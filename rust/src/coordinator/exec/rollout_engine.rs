//! Real multi-threaded rollout generation over the chunked decode driver.
//!
//! An iteration's generation is planned as a refill queue of rows
//! ([`crate::rollout::plan_rows`] — one row per rollout, each with a
//! private RNG seed) and fanned over a pool of OS threads as contiguous
//! **row shards**: every worker runs its own slot-based continuous
//! batcher ([`crate::rollout::decode_rows`]) over its shard — retiring
//! rows at EOS, admitting queued rows into freed slots, exiting early
//! when its shard drains.
//!
//! The PJRT [`Engine`] is not `Send`/`Sync` (single-threaded client,
//! `Rc`-cached executables), so the pool cannot share the trainer's
//! engine. Instead **each worker thread lazily loads its own engine
//! replica** of the same artifact profile — the replica compiles the
//! decode programs once on first use and is reused for the rest of the
//! run. Inputs cross the thread boundary as [`GenBatch`] snapshots
//! (`Arc`-shared parameter vectors + problems), which is exactly the
//! snapshot semantics the pipelined schedule needs anyway: generation of
//! iteration *t+1* runs against the pre-update policy while the main
//! thread updates.
//!
//! Determinism: every row's token stream is a counter-based function of
//! its own seed, so sharding — like chunking and refill order — cannot
//! change what any rollout samples. `workers = 16` produces bit-identical
//! rollouts to `workers = 1`; only the call-count/decoded-token telemetry
//! (how the physical work was batched) varies with the partition.
//!
//! **Fault tolerance** (`[faults]`): when a [`FaultPlan`] rides in the
//! batch, each row-attempt consults the seeded fault schedule *before*
//! decoding — faulted rows are withheld from the attempt and resubmitted
//! as retry jobs (fresh shard indices, `attempt + 1`) up to
//! `faults.max_retries`, after which they count as lost. Because the
//! schedule keys on row identity — never on the physical shard — and
//! retried rows replay their private counter-based streams bit-exactly,
//! the surviving rollouts are identical across worker-pool sizes. Real
//! shard errors (panics, engine failures) reuse the same retry path;
//! a [`KvAdmissionError`] is a deterministic pathology that retrying
//! cannot fix, so its rows are lost immediately (accounted as admission
//! faults). With `[faults]` disabled every error stays loud, exactly as
//! before.

use crate::coordinator::group::PromptGroup;
use crate::coordinator::scheduler::{BudgetAllocator, BudgetSpec};
use crate::coordinator::select::online::GroupVerdicts;
use crate::hwsim::{FaultKind, FaultPlan};
use crate::reward::RewardWeights;
use crate::rollout::{
    execute_rows, plan_rows, row_seed, CallRollout, InferenceStats, KvAdmissionError, KvPolicy,
    RefillMode, RowSpec,
};
use crate::runtime::Engine;
use crate::tasks::{Problem, TaskKind};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeSet, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Everything one iteration's generation needs, snapshotted so worker
/// threads (and the pipelined schedule) can run it independently of the
/// trainer's live parameter store.
#[derive(Debug, Clone)]
pub struct GenBatch {
    /// Full-parameter vector rollouts decode with (the frozen base in
    /// LoRA profiles).
    pub params: Arc<Vec<f32>>,
    /// Trainable adapter vector (LoRA profiles only).
    pub lora: Option<Arc<Vec<f32>>>,
    /// Reference-policy parameters for the KL term (when kl_coef > 0).
    pub ref_params: Option<Arc<Vec<f32>>>,
    /// Reference-policy adapter vector (LoRA profiles with KL).
    pub ref_lora: Option<Arc<Vec<f32>>>,
    /// The iteration's prompt batch, one group per problem.
    pub problems: Arc<Vec<Problem>>,
    /// Rollouts per prompt (the paper's `n`).
    pub n: usize,
    /// Sampling temperature.
    pub temperature: f32,
    /// Run seed — one axis of every row's private stream seed.
    pub run_seed: u64,
    /// Training iteration this generation belongs to.
    pub iter: u64,
    /// Task family verifying the generated answers.
    pub task: TaskKind,
    /// Reward component weights.
    pub weights: RewardWeights,
    /// Tokens decoded per `decode_chunk` call (`[rollout] decode_chunk`).
    pub decode_chunk: usize,
    /// Slot-refill policy (`[rollout] refill`).
    pub refill: RefillMode,
    /// Shared per-group online-pruning verdict state for this batch
    /// (`[rollout] online_prune`). One aggregator serves every worker
    /// shard — a group's rows can span shards, and all of them observe
    /// and poll the same state. `None` disables pruning.
    pub online: Option<Arc<GroupVerdicts>>,
    /// KV accounting policy (`[rollout] share_prompt_kv` plus the hwsim
    /// paged-pool model). Each worker shard runs its own pool ledger;
    /// `KvPolicy::default()` is the legacy per-row-prefill path.
    pub kv: KvPolicy,
    /// Seeded fault schedule (`[faults]`); `None` disables injection and
    /// keeps every executor error loud.
    pub faults: Option<FaultPlan>,
    /// Adaptive per-prompt rollout budget (`[budget]`). When set,
    /// generation runs in two waves: a probe wave of `n_probe` rows per
    /// group, then — at the probe barrier — a
    /// [`BudgetAllocator`] streams the remaining `(n − n_probe) × groups`
    /// slots to groups whose observed reward bracket is still wide. The
    /// allocation is a pure function of the assembled probe outcomes
    /// (never of shard layout or completion order), and extra rows draw
    /// their seeds from the same `row_seed` axis, so budgeted runs stay
    /// bit-invariant to worker count and chunk size. `None` keeps the
    /// fixed-`n` plan.
    pub budget: Option<BudgetSpec>,
}

/// One queued shard of generation rows for a worker thread.
struct Job {
    batch_id: u64,
    shard_idx: usize,
    /// Which execution attempt this job is (0 = first, 1.. = retries).
    attempt: usize,
    rows: Vec<RowSpec>,
    batch: Arc<GenBatch>,
}

/// One shard attempt's outcome: finished rollouts, its stats, and the
/// rows the fault schedule withheld from this attempt (to be retried or
/// declared lost by the caller).
type ShardOut = (Vec<CallRollout>, InferenceStats, Vec<RowSpec>);

/// What a worker thread reports back.
enum WorkerMsg {
    /// A shard attempt completed (successfully or not). `rows` echoes the
    /// job's row set so the caller can retry a failed attempt.
    Shard {
        batch_id: u64,
        attempt: usize,
        rows: Vec<RowSpec>,
        result: Result<ShardOut>,
    },
    /// The worker thread itself is gone (e.g. it observed a poisoned
    /// work-queue lock). Previously this was a silent `return` that could
    /// leave `collect()` waiting forever; now lost capacity is always
    /// visible.
    WorkerLost { reason: String },
}

struct Pool {
    job_tx: mpsc::Sender<Job>,
    result_rx: mpsc::Receiver<WorkerMsg>,
    handles: Vec<JoinHandle<()>>,
}

/// Handle to an in-flight generation batch (pipelined prefetch). Redeem
/// with [`RolloutEngine::collect`].
pub struct PendingGen {
    batch_id: u64,
    shards: usize,
    /// The profile's rollout batch size — kept so the budget extra wave
    /// shards with the same granularity rule as the probe wave.
    br: usize,
    batch: Arc<GenBatch>,
}

impl PendingGen {
    /// The snapshot the in-flight generation decodes with (checkpointing
    /// reads the behaviour params out of it to make pipelined resume
    /// bit-exact).
    pub fn batch(&self) -> &GenBatch {
        &self.batch
    }
}

/// A pool of rollout worker threads, each owning an engine replica.
///
/// With `workers <= 1`, [`Self::generate`] runs inline on the trainer's
/// engine (no replica, no thread hop) with a single refill queue — the
/// maximum continuous-batching benefit. [`Self::submit`] always uses the
/// pool: a dedicated thread is what lets generation overlap the
/// main-thread update even with one simulated worker.
pub struct RolloutEngine {
    artifacts: PathBuf,
    profile: String,
    /// Configured pool size (`hwsim.workers`); the real thread count is
    /// capped at host parallelism.
    pub workers: usize,
    pool: Option<Pool>,
    next_batch_id: u64,
    /// Batch ids submitted but not yet collected. The staleness-K fleet
    /// schedule keeps several generations in flight at once; the set is
    /// what tells a collect loop whether a foreign shard result belongs
    /// to a live sibling (park it) or a discarded batch (drop it).
    in_flight: BTreeSet<u64>,
    /// Shard results that arrived while a *different* live batch was
    /// being collected, parked until their own batch's collect drains
    /// them. Completion order across batches is a thread-timing artifact;
    /// parking is what keeps each batch's assembly a pure function of its
    /// own row set (docs/DETERMINISM.md).
    parked: VecDeque<WorkerMsg>,
}

/// Split the row queue into contiguous, size-balanced shards: at most
/// one per worker, but never more than `ceil(rows / B_r)` — a shard
/// smaller than the rollout batch decodes mostly filler slots, so spare
/// workers are better left idle than fed under-full batches. Empty
/// shards are never produced.
fn shard_rows(rows: &[RowSpec], workers: usize, br: usize) -> Vec<Vec<RowSpec>> {
    let full_batches = rows.len().div_ceil(br.max(1));
    let shards = workers.min(full_batches).clamp(1, rows.len().max(1));
    let base = rows.len() / shards;
    let extra = rows.len() % shards;
    let mut out = Vec::with_capacity(shards);
    let mut off = 0;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        if len == 0 {
            continue;
        }
        out.push(rows[off..off + len].to_vec());
        off += len;
    }
    out
}

impl RolloutEngine {
    /// An engine over `profile`'s artifacts with a pool of `workers`
    /// threads (spawned lazily on first use).
    pub fn new(artifacts: PathBuf, profile: impl Into<String>, workers: usize) -> Self {
        Self {
            artifacts,
            profile: profile.into(),
            workers,
            pool: None,
            next_batch_id: 0,
            in_flight: BTreeSet::new(),
            parked: VecDeque::new(),
        }
    }

    /// Spawn the worker threads on first use (engine replicas load lazily
    /// inside each thread, on its first job). The real thread count is
    /// capped at the host's parallelism — simulating 8 accelerators on a
    /// 4-core laptop must not oversubscribe it with 8 engine replicas;
    /// results are bit-identical for any pool size.
    fn ensure_pool(&mut self) -> Result<&Pool> {
        if self.pool.is_none() {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let threads = self.workers.clamp(1, cores.max(1));
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let job_rx = Arc::new(Mutex::new(job_rx));
            let (res_tx, result_rx) = mpsc::channel::<WorkerMsg>();
            let mut handles = Vec::with_capacity(threads);
            for w in 0..threads {
                let rx = Arc::clone(&job_rx);
                let tx = res_tx.clone();
                let artifacts = self.artifacts.clone();
                let profile = self.profile.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("rollout-worker-{w}"))
                    .spawn(move || worker_main(artifacts, profile, rx, tx))
                    .with_context(|| format!("spawning rollout worker {w}"))?;
                handles.push(handle);
            }
            self.pool = Some(Pool { job_tx, result_rx, handles });
        }
        Ok(self.pool.as_ref().expect("just ensured"))
    }

    /// Generate every group of `batch` synchronously and return them in
    /// prompt order with the aggregated inference stats.
    pub fn generate(
        &mut self,
        engine: &Engine,
        batch: GenBatch,
    ) -> Result<(Vec<PromptGroup>, InferenceStats)> {
        let rows = plan_rows(&batch.problems, probe_n(&batch), batch.run_seed, batch.iter);
        if self.workers <= 1 {
            // inline: one continuous queue over all rows — no replica, no
            // thread hop, maximal refill packing. Retries loop locally
            // with the same semantics as the pool path.
            return generate_inline(engine, &batch, rows);
        }
        let br = engine.meta.config.rollout_batch;
        let pending = self.submit_rows(rows, Arc::new(batch), br)?;
        self.collect(pending)
    }

    /// Start generating `batch` on the pool and return immediately — the
    /// async schedules' prefetch. `br` is the profile's rollout batch
    /// size (`engine.meta.config.rollout_batch`), which bounds how finely
    /// the rows are sharded. Several batches may be in flight at once
    /// (the staleness-K ready-batch queue); each one's shard results are
    /// keyed by batch id and collected independently. Under a `[budget]`
    /// the submitted wave covers only the probe quota; the budget extra
    /// wave runs inside [`Self::collect`], after the probe outcomes are
    /// assembled — per batch, so every in-flight generation runs its own
    /// probe barrier.
    pub fn submit(&mut self, br: usize, batch: GenBatch) -> Result<PendingGen> {
        let rows = plan_rows(&batch.problems, probe_n(&batch), batch.run_seed, batch.iter);
        self.submit_rows(rows, Arc::new(batch), br)
    }

    fn submit_rows(
        &mut self,
        rows: Vec<RowSpec>,
        batch: Arc<GenBatch>,
        br: usize,
    ) -> Result<PendingGen> {
        let batch_id = self.next_batch_id;
        self.next_batch_id += 1;
        let shards = shard_rows(&rows, self.workers.max(1), br);
        let n_shards = shards.len();
        let pool = self.ensure_pool()?;
        for (shard_idx, rows) in shards.into_iter().enumerate() {
            pool.job_tx
                .send(Job { batch_id, shard_idx, attempt: 0, rows, batch: Arc::clone(&batch) })
                .map_err(|_| anyhow!("rollout worker threads exited; pool is gone"))?;
        }
        self.in_flight.insert(batch_id);
        Ok(PendingGen { batch_id, shards: n_shards, br, batch })
    }

    /// Block until every shard of `pending` finished (retrying failed
    /// shards up to `faults.max_retries` when fault injection is on) and
    /// assemble the groups in canonical plan order — rollouts sort by
    /// their in-group index, so worker completion order and retry timing
    /// cannot reorder anything.
    ///
    /// Under a `[budget]`, the submitted shards are the **probe wave**;
    /// once it drains, the allocator converts the assembled probe
    /// outcomes into extra rows and a second wave runs through the same
    /// shard/retry machinery. The probe barrier is what makes the
    /// allocation partition-pure: every worker layout observes the exact
    /// same probe history before any extra slot is granted.
    pub fn collect(&mut self, pending: PendingGen) -> Result<(Vec<PromptGroup>, InferenceStats)> {
        // collect() consumes the in-flight batch whatever happens next —
        // its stragglers must be dropped (not parked) once it is no
        // longer live, and a broken pool must surface its own error on
        // later submits.
        self.in_flight.remove(&pending.batch_id);
        let workers = self.workers.max(1);
        let pool = self
            .pool
            .as_ref()
            .ok_or_else(|| anyhow!("collect without a running pool"))?;
        let mut wave = WaveState {
            alive: pool.handles.len(),
            next_shard_idx: pending.shards,
            kept: Vec::new(),
            stats: InferenceStats::default(),
        };
        collect_wave(
            pool,
            &pending,
            pending.shards,
            &mut wave,
            &mut self.parked,
            &self.in_flight,
        )?;
        if let Some(spec) = pending.batch.budget {
            let extras = plan_extra_rows(&pending.batch, spec, &wave.kept, &mut wave.stats);
            if !extras.is_empty() {
                let shards = shard_rows(&extras, workers, pending.br);
                let n_shards = shards.len();
                for rows in shards {
                    pool.job_tx
                        .send(Job {
                            batch_id: pending.batch_id,
                            shard_idx: wave.next_shard_idx,
                            attempt: 0,
                            rows,
                            batch: Arc::clone(&pending.batch),
                        })
                        .map_err(|_| {
                            anyhow!("rollout worker threads exited before the budget wave")
                        })?;
                    wave.next_shard_idx += 1;
                }
                collect_wave(
                    pool,
                    &pending,
                    n_shards,
                    &mut wave,
                    &mut self.parked,
                    &self.in_flight,
                )?;
            }
        }
        Ok(assemble(&pending.batch, wave.kept, wave.stats))
    }
}

/// Mutable receive-loop state threaded through the waves of one
/// [`RolloutEngine::collect`] call (the budget extra wave continues where
/// the probe wave left off: same kept set, same stats, fresh shard
/// indices, and worker losses carry over).
struct WaveState {
    alive: usize,
    next_shard_idx: usize,
    kept: Vec<CallRollout>,
    stats: InferenceStats,
}

/// Drain `outstanding` shards of `pending` from the pool, retrying failed
/// attempts per the batch's fault plan. One wave of the collect loop.
///
/// With several batches in flight, shard results interleave on the one
/// result channel: results already parked for `pending` are consumed
/// first, results for a *live* sibling batch (in `live`) are parked for
/// that batch's own collect, and stragglers of discarded batches are
/// dropped.
fn collect_wave(
    pool: &Pool,
    pending: &PendingGen,
    outstanding: usize,
    wave: &mut WaveState,
    parked: &mut VecDeque<WorkerMsg>,
    live: &BTreeSet<u64>,
) -> Result<()> {
    let plan = pending.batch.faults.clone();
    let mut alive = wave.alive;
    let mut next_shard_idx = wave.next_shard_idx;
    let kept = &mut wave.kept;
    let stats = &mut wave.stats;
    let mut outstanding = outstanding;
    let mut last_lost_reason = String::new();
    let is_ours = |m: &WorkerMsg| {
        matches!(m, WorkerMsg::Shard { batch_id, .. } if *batch_id == pending.batch_id)
    };
    while outstanding > 0 {
        let msg = if let Some(pos) = parked.iter().position(is_ours) {
            parked.remove(pos).expect("position found above")
        } else if alive > 0 {
            pool.result_rx
                .recv()
                .map_err(|_| anyhow!("rollout workers hung up mid-batch"))?
        } else {
            // no worker remains to produce results: drain what is
            // already buffered, then fail loudly on the missing shards
            match pool.result_rx.try_recv() {
                Ok(m) => m,
                Err(_) => bail!(
                    "all rollout workers lost ({last_lost_reason}); \
                     {outstanding} shard(s) never completed"
                ),
            }
        };
        let (attempt, rows, result) = match msg {
            WorkerMsg::WorkerLost { reason } => {
                alive = alive.saturating_sub(1);
                last_lost_reason = reason;
                continue;
            }
            WorkerMsg::Shard { batch_id, attempt, rows, result } => {
                if batch_id != pending.batch_id {
                    if live.contains(&batch_id) {
                        // a queued sibling's shard finished early: park
                        // it for that batch's own collect loop
                        parked.push_back(WorkerMsg::Shard { batch_id, attempt, rows, result });
                    }
                    continue; // stragglers of a discarded batch
                }
                (attempt, rows, result)
            }
        };
        outstanding -= 1;
        match result {
            Ok((shard_kept, shard_stats, failed)) => {
                stats.absorb(&shard_stats);
                kept.extend(shard_kept);
                if failed.is_empty() {
                    continue;
                }
                match &plan {
                    Some(p) if attempt < p.cfg.max_retries => {
                        stats.shard_retries += 1;
                        pool.job_tx
                            .send(Job {
                                batch_id: pending.batch_id,
                                shard_idx: next_shard_idx,
                                attempt: attempt + 1,
                                rows: failed,
                                batch: Arc::clone(&pending.batch),
                            })
                            .map_err(|_| anyhow!("rollout worker threads exited mid-retry"))?;
                        next_shard_idx += 1;
                        outstanding += 1;
                    }
                    _ => stats.rows_lost += failed.len(),
                }
            }
            Err(e) => match &plan {
                // no fault layer: every shard error stays loud
                None => return Err(e.context("rollout shard failed")),
                Some(p) => {
                    if e.downcast_ref::<KvAdmissionError>().is_some() {
                        // deterministic pathology — the pool can never
                        // hold the row, so retrying cannot help; the
                        // rows are lost as admission faults and the
                        // min_group_survivors floor decides loudness
                        stats.faults_injected += rows.len();
                        stats.rows_lost += rows.len();
                    } else if attempt < p.cfg.max_retries {
                        stats.shard_retries += 1;
                        stats.fault_backoff_time += p.backoff(attempt);
                        pool.job_tx
                            .send(Job {
                                batch_id: pending.batch_id,
                                shard_idx: next_shard_idx,
                                attempt: attempt + 1,
                                rows,
                                batch: Arc::clone(&pending.batch),
                            })
                            .map_err(|_| anyhow!("rollout worker threads exited mid-retry"))?;
                        next_shard_idx += 1;
                        outstanding += 1;
                    } else {
                        stats.rows_lost += rows.len();
                    }
                }
            },
        }
    }
    wave.alive = alive;
    wave.next_shard_idx = next_shard_idx;
    Ok(())
}

/// How many rows per group the first decode wave plans: the probe quota
/// under a `[budget]`, the full `n` otherwise.
fn probe_n(batch: &GenBatch) -> usize {
    batch.budget.map(|b| b.n_probe.min(batch.n)).unwrap_or(batch.n)
}

/// The probe barrier: fold the assembled probe outcomes into a
/// [`BudgetAllocator`] and plan the extra-wave rows it grants.
///
/// Only unpruned rows observe — exactly the rewards the online verdict
/// state ([`GroupVerdicts`]) saw retire, since aborted rows never reach
/// `on_retired`. The observation fold is commutative (min/max), so the
/// allocation is independent of the order probe rows completed in; rows
/// lost to faults shrink the observation set identically across
/// partitions because the fault plan keys on row identity. Extra rows
/// take rollout indices `n_probe..` and draw seeds from the same
/// `row_seed` axis as planned rows — their token streams need no new
/// determinism machinery. When the batch carries online-pruning verdict
/// state, each granted group is grown to its new size so the extra rows
/// are observable and doomable like any probe row.
fn plan_extra_rows(
    batch: &GenBatch,
    spec: BudgetSpec,
    kept: &[CallRollout],
    stats: &mut InferenceStats,
) -> Vec<RowSpec> {
    let mut alloc = BudgetAllocator::new(spec, batch.problems.len());
    for cr in kept {
        if !cr.record.pruned {
            alloc.observe(cr.group_idx, cr.record.total_reward);
        }
    }
    let grants = alloc.allocate();
    stats.budget_extra_rows = grants.len();
    stats.budget_saturated_groups = alloc.saturated_groups();
    if let Some(verdicts) = &batch.online {
        let mut add = vec![0usize; batch.problems.len()];
        for &(g, _) in &grants {
            add[g] += 1;
        }
        for (g, a) in add.into_iter().enumerate() {
            if a > 0 {
                verdicts.grow_group(g, a);
            }
        }
    }
    grants
        .into_iter()
        .map(|(g, r)| RowSpec {
            group_idx: g,
            rollout_idx: r as usize,
            seed: row_seed(batch.run_seed, batch.iter, batch.problems[g].id, r as u64),
        })
        .collect()
}

impl Drop for RolloutEngine {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            drop(pool.job_tx); // workers exit when the job channel closes
            drop(pool.result_rx);
            for h in pool.handles {
                let _ = h.join();
            }
        }
    }
}

/// The inline (workers <= 1) generation path with the same
/// retry/degradation semantics as the pool path, including the budget
/// probe barrier: probe wave, allocator, extra wave, one assembly.
fn generate_inline(
    engine: &Engine,
    batch: &GenBatch,
    rows: Vec<RowSpec>,
) -> Result<(Vec<PromptGroup>, InferenceStats)> {
    let mut stats = InferenceStats::default();
    let mut kept: Vec<CallRollout> = Vec::new();
    run_rows_inline(engine, batch, rows, &mut kept, &mut stats)?;
    if let Some(spec) = batch.budget {
        let extras = plan_extra_rows(batch, spec, &kept, &mut stats);
        if !extras.is_empty() {
            run_rows_inline(engine, batch, extras, &mut kept, &mut stats)?;
        }
    }
    Ok(assemble(batch, kept, stats))
}

/// Run one wave of rows on the trainer's own engine, looping local
/// retries with the pool path's fault semantics.
fn run_rows_inline(
    engine: &Engine,
    batch: &GenBatch,
    rows: Vec<RowSpec>,
    kept: &mut Vec<CallRollout>,
    stats: &mut InferenceStats,
) -> Result<()> {
    let mut pending_rows = rows;
    let mut attempt = 0usize;
    loop {
        match run_shard(engine, batch, &pending_rows, attempt) {
            Ok((k, s, failed)) => {
                stats.absorb(&s);
                kept.extend(k);
                if failed.is_empty() {
                    break;
                }
                match &batch.faults {
                    Some(p) if attempt < p.cfg.max_retries => {
                        stats.shard_retries += 1;
                        pending_rows = failed;
                        attempt += 1;
                    }
                    _ => {
                        stats.rows_lost += failed.len();
                        break;
                    }
                }
            }
            Err(e) => match &batch.faults {
                None => return Err(e),
                Some(p) => {
                    if e.downcast_ref::<KvAdmissionError>().is_some() {
                        stats.faults_injected += pending_rows.len();
                        stats.rows_lost += pending_rows.len();
                        break;
                    } else if attempt < p.cfg.max_retries {
                        stats.shard_retries += 1;
                        stats.fault_backoff_time += p.backoff(attempt);
                        attempt += 1;
                    } else {
                        stats.rows_lost += pending_rows.len();
                        break;
                    }
                }
            },
        }
    }
    Ok(())
}

/// Execute one row shard against an engine (worker replica or the
/// trainer's own engine on the inline path). With a fault plan in the
/// batch, each row's fate at `attempt` is drawn **before** decoding:
/// faulted rows are withheld (returned for retry) so a row is only ever
/// observed by the online-pruning verdicts on the attempt that actually
/// decodes it, and straggler rows accumulate their slowdown charge.
fn run_shard(
    engine: &Engine,
    batch: &GenBatch,
    rows: &[RowSpec],
    attempt: usize,
) -> Result<ShardOut> {
    let mut fault_stats = InferenceStats::default();
    let mut healthy: Vec<RowSpec> = Vec::with_capacity(rows.len());
    let mut failed: Vec<RowSpec> = Vec::new();
    if let Some(plan) = &batch.faults {
        let g = engine.meta.gen_len;
        for &r in rows {
            let pid = batch.problems[r.group_idx].id;
            match plan.row_fault(batch.iter, pid, r.rollout_idx as u64, attempt) {
                None => healthy.push(r),
                Some(kind) => {
                    fault_stats.faults_injected += 1;
                    if kind == FaultKind::Crash {
                        // the crashed attempt decoded, then lost, its
                        // generation budget — charged as wasted work
                        fault_stats.fault_wasted_tokens += g;
                    }
                    if attempt < plan.cfg.max_retries {
                        fault_stats.fault_backoff_time += plan.backoff(attempt);
                    }
                    failed.push(r);
                }
            }
        }
    } else {
        healthy.extend_from_slice(rows);
    }
    let (kept, mut stats) = execute_rows(
        engine,
        &batch.params,
        batch.lora.as_deref().map(|v| v.as_slice()),
        batch.ref_params.as_deref().map(|v| v.as_slice()),
        batch.ref_lora.as_deref().map(|v| v.as_slice()),
        batch.temperature,
        batch.decode_chunk,
        batch.refill,
        &healthy,
        &batch.problems,
        batch.task,
        &batch.weights,
        batch.online.as_deref(),
        batch.kv,
    )?;
    if let Some(plan) = &batch.faults {
        let chunk = batch.decode_chunk.max(1);
        for cr in &kept {
            // pruned rows' decoded lengths depend on abort timing (a
            // partition effect), so only finished rows draw stragglers —
            // their lengths are stream-determined and partition-invariant
            if cr.record.pruned {
                continue;
            }
            let pid = batch.problems[cr.group_idx].id;
            if plan.row_straggler(batch.iter, pid, cr.rollout_idx as u64) {
                let len = cr.record.gen_len.max(0) as usize;
                stats.straggler_tokens += len.div_ceil(chunk) * chunk;
            }
        }
    }
    stats.absorb(&fault_stats);
    Ok((kept, stats, failed))
}

/// Reassemble finished rollouts into per-prompt groups in canonical
/// order: rollouts sort by their in-group index, so shard layout, worker
/// completion order and retry timing cannot reorder a group. Lost rows
/// simply leave gaps — the selector clamps `m` to what survived.
fn assemble(
    batch: &GenBatch,
    kept: Vec<CallRollout>,
    mut stats: InferenceStats,
) -> (Vec<PromptGroup>, InferenceStats) {
    let mut per_group: Vec<Vec<CallRollout>> =
        batch.problems.iter().map(|_| Vec::with_capacity(batch.n)).collect();
    for cr in kept {
        per_group[cr.group_idx].push(cr);
    }
    let mut groups: Vec<PromptGroup> = Vec::with_capacity(batch.problems.len());
    for (p, mut rollouts) in batch.problems.iter().zip(per_group) {
        rollouts.sort_by_key(|c| c.rollout_idx);
        groups.push(PromptGroup {
            problem: p.clone(),
            rollouts: rollouts.into_iter().map(|c| c.record).collect(),
        });
    }
    stats.rollouts = groups.iter().map(|g| g.rollouts.len()).sum();
    (groups, stats)
}

/// Worker thread body: pull shards off the shared queue until the channel
/// closes. The engine replica is loaded on the first job so idle pools
/// (e.g. sync schedule with one worker) never pay a compile.
fn worker_main(
    artifacts: PathBuf,
    profile: String,
    jobs: Arc<Mutex<mpsc::Receiver<Job>>>,
    results: mpsc::Sender<WorkerMsg>,
) {
    let mut engine: Option<Engine> = None;
    loop {
        // Holding the lock only while blocked in recv: exactly one idle
        // worker waits inside recv at a time; the others queue on the
        // mutex and all of them *process* jobs concurrently.
        let job = match jobs.lock() {
            Ok(rx) => rx.recv(),
            Err(_) => {
                // poisoned: a sibling panicked while holding the lock.
                // Report the lost worker instead of silently returning —
                // otherwise collect() can wait forever on shards nobody
                // will ever run.
                let _ = results.send(WorkerMsg::WorkerLost {
                    reason: "work-queue lock poisoned by a sibling panic".to_string(),
                });
                return;
            }
        };
        let Ok(job) = job else { return }; // channel closed: shutdown
        if engine.is_none() {
            match Engine::load(&artifacts, &profile) {
                Ok(mut e) => {
                    e.quiet = true;
                    engine = Some(e);
                }
                Err(e) => {
                    let msg = anyhow!("rollout worker failed to load engine replica: {e}");
                    let _ = results.send(WorkerMsg::Shard {
                        batch_id: job.batch_id,
                        attempt: job.attempt,
                        rows: job.rows,
                        result: Err(msg),
                    });
                    continue;
                }
            }
        }
        // A panicking shard must still produce a ShardResult — otherwise
        // collect() would wait forever for the missing slot. The replica
        // is discarded after a panic (its internal state is suspect).
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_shard(engine.as_ref().expect("loaded above"), &job.batch, &job.rows, job.attempt)
        }));
        let res = match caught {
            Ok(r) => r,
            Err(panic) => {
                engine = None;
                let what = panic
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                Err(anyhow!("rollout worker panicked executing shard: {what}"))
            }
        };
        let msg = WorkerMsg::Shard {
            batch_id: job.batch_id,
            attempt: job.attempt,
            rows: job.rows,
            result: res,
        };
        if results.send(msg).is_err() {
            return; // receiver gone: engine shut down
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize) -> Vec<RowSpec> {
        (0..n).map(|i| RowSpec { group_idx: i / 4, rollout_idx: i % 4, seed: i as i32 }).collect()
    }

    /// Sharding is contiguous, balanced, covers every row exactly once,
    /// never emits empty shards, and never splits finer than the rollout
    /// batch allows (under-full decode batches waste slots on filler).
    #[test]
    fn shard_rows_partitions_contiguously() {
        for (n, w, br) in [
            (12usize, 4usize, 4usize),
            (13, 4, 4),
            (3, 8, 4),
            (1, 1, 4),
            (16, 1, 4),
            (64, 8, 16),
        ] {
            let all = rows(n);
            let shards = shard_rows(&all, w, br);
            assert!(shards.len() <= w.max(1));
            assert!(shards.len() <= n.div_ceil(br).max(1), "over-sharded at n={n} w={w}");
            assert!(shards.iter().all(|s| !s.is_empty()));
            let flat: Vec<i32> = shards.iter().flatten().map(|r| r.seed).collect();
            let want: Vec<i32> = all.iter().map(|r| r.seed).collect();
            assert_eq!(flat, want, "sharding reordered rows at n={n} w={w}");
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced shards {sizes:?}");
        }
        // 64 rows, 8 workers, B_r=16: only 4 shards — each worker batch full
        assert_eq!(shard_rows(&rows(64), 8, 16).len(), 4);
        // 3 rows on 8 workers collapse to one shard
        assert_eq!(shard_rows(&rows(3), 8, 4).len(), 1);
    }

    /// Out-of-order arrival (retries completing late) cannot perturb group
    /// assembly: rollouts sort back into canonical in-group order.
    #[test]
    fn assemble_restores_canonical_order() {
        use crate::coordinator::group::PromptGroup as PG;
        let problems: Vec<Problem> =
            (0..2u64).map(|i| TaskKind::Arith.generate(crate::tasks::Split::Train, i)).collect();
        let batch = GenBatch {
            params: Arc::new(vec![]),
            lora: None,
            ref_params: None,
            ref_lora: None,
            problems: Arc::new(problems),
            n: 3,
            temperature: 1.0,
            run_seed: 0,
            iter: 0,
            task: TaskKind::Arith,
            weights: RewardWeights::default(),
            decode_chunk: 16,
            refill: RefillMode::Continuous,
            online: None,
            kv: KvPolicy::default(),
            faults: None,
            budget: None,
        };
        let synth = PG::synthetic(0, &[1.0, 2.0, 3.0], None);
        // rollouts arrive scrambled across groups and indices
        let kept: Vec<CallRollout> = vec![
            (1, 2),
            (0, 1),
            (1, 0),
            (0, 0),
            (0, 2),
        ]
        .into_iter()
        .map(|(g, j)| CallRollout {
            group_idx: g,
            rollout_idx: j,
            record: {
                let mut r = synth.rollouts[j].clone();
                r.total_reward = (g * 10 + j) as f32;
                r
            },
        })
        .collect();
        let (groups, stats) = assemble(&batch, kept, InferenceStats::default());
        assert_eq!(groups[0].rollouts.len(), 3);
        // group 1 lost rollout_idx 1 — a gap, not a reorder
        assert_eq!(groups[1].rollouts.len(), 2);
        let rewards0: Vec<f32> = groups[0].rollouts.iter().map(|r| r.total_reward).collect();
        assert_eq!(rewards0, vec![0.0, 1.0, 2.0]);
        let rewards1: Vec<f32> = groups[1].rollouts.iter().map(|r| r.total_reward).collect();
        assert_eq!(rewards1, vec![10.0, 12.0]);
        assert_eq!(stats.rollouts, 5);
    }
}
