//! The policy-update phase as a **sharded data-parallel engine**:
//! micro-batch packing, per-shard scheduling, gradient accumulation, a
//! simulated ring all-reduce, and the fused optimizer apply.
//!
//! ## Topology
//!
//! The kept rollouts are packed into micro-batches of
//! `update.micro_batch` rows (default: the profile's full `B_u`), each
//! executed through the fixed-shape AOT `grad` program with unused slots
//! padded (padded rows carry zero advantage and contribute exactly zero
//! gradient). The micro-batch sequence is then split into `update.shards`
//! contiguous device shards ([`ShardPlan`]): shards run their micro-steps
//! in parallel in the cost model, gradients all-reduce once per optimizer
//! step (DDP `no_sync` accumulation semantics), and one AdamW apply
//! finishes the iteration.
//!
//! ## Determinism contract (docs/DETERMINISM.md)
//!
//! Physical execution happens on the host's single PJRT device whatever
//! the simulated topology, and gradients accumulate in **canonical global
//! micro-batch order** into one f32 buffer — the simulated collective is
//! order-stable, unlike a real NCCL ring. Two consequences, both pinned
//! by tests:
//!
//! * **Shard invariance** — trained parameters are bit-identical for any
//!   `update.shards`; the topology only moves simulated cost
//!   (`max(compute_shard) + allreduce + optimizer`, see
//!   [`crate::hwsim::HwModel::update_cost`]).
//! * **Default micro-batch replays the monolith** — with
//!   `micro_batch = 0` the packing is exactly the legacy single-shot
//!   engine's `chunks(B_u)`, so the update is bit-identical to the
//!   pre-sharding trainer. A non-default micro-batch changes which rows
//!   share a device reduction, so its parameters are reproducible but not
//!   comparable across micro-batch sizes.
//!
//! An iteration whose selection dropped every group performs (and is
//! charged) nothing.
//!
//! ## Replay mixing (`[replay]`)
//!
//! When the cross-iteration replay store drew rows for this update, they
//! are appended **after** the fresh selected rows in the canonical
//! packing order, carrying their stored behaviour log-probs (floored at
//! `-ln(rho_max)`, see [`crate::coordinator::replay::truncate_old_lp`])
//! and their admission-time advantages. The plan spans
//! `selected + replayed` rows, so replayed rows are charged full update
//! cost; with replay disabled or the store empty the packing — and every
//! f32 rounding step after it — is bit-identical to a build without the
//! replay subsystem.

use crate::config::RunConfig;
use crate::coordinator::accum::GradAccumulator;
use crate::coordinator::group::{PromptGroup, SelectedRollout};
use crate::coordinator::replay::{truncate_old_lp, StoredRow};
use crate::runtime::{Engine, MicroBatch, ParamStore, TensorF, TensorI};
use anyhow::Result;

/// One update-ready row, as the shared micro-batch packer consumes it:
/// borrowed slices into wherever the row lives (a fresh
/// [`crate::coordinator::group::RolloutRecord`], a replayed
/// [`StoredRow`], or a rollout-program output buffer).
#[derive(Debug, Clone, Copy)]
pub struct PackedRow<'a> {
    /// Full token row `[T]` (left-padded prompt + generation).
    pub tokens: &'a [i32],
    /// Left-padding length of the prompt region.
    pub pad_len: i32,
    /// `[G]` generation mask, 1.0 through EOS.
    pub gen_mask: &'a [f32],
    /// `[G]` behaviour log-probs the ratio term divides by.
    pub old_lp: &'a [f32],
    /// `[G]` reference-policy log-probs (zeros when KL is off).
    pub ref_lp: &'a [f32],
    /// Normalized advantage.
    pub advantage: f32,
}

/// Pack up to `bu` rows into one fixed-shape `[B_u]` micro-batch for the
/// AOT `grad` program. Unused slots stay padded (PAD tokens, zero masks,
/// zero advantage) and contribute exactly zero gradient.
///
/// This is the **single** packing path: the training update, the replay
/// mix and `exp fig1`'s probe all build their micro-batches here, so the
/// buffer layout can never diverge between the trainer and the
/// experiment drivers.
pub fn pack_micro_batch(rows: &[PackedRow], bu: usize, g: usize, t: usize) -> Result<MicroBatch> {
    let mut tokens = vec![crate::tasks::tokenizer::PAD; bu * t];
    let mut pads = vec![0i32; bu];
    let mut gen_mask = vec![0.0f32; bu * g];
    let mut old_lp = vec![0.0f32; bu * g];
    let mut ref_lp = vec![0.0f32; bu * g];
    let mut adv = vec![0.0f32; bu];
    for (b, row) in rows.iter().enumerate().take(bu) {
        tokens[b * t..(b + 1) * t].copy_from_slice(row.tokens);
        pads[b] = row.pad_len;
        gen_mask[b * g..(b + 1) * g].copy_from_slice(row.gen_mask);
        old_lp[b * g..(b + 1) * g].copy_from_slice(row.old_lp);
        ref_lp[b * g..(b + 1) * g].copy_from_slice(row.ref_lp);
        adv[b] = row.advantage;
    }
    Ok(MicroBatch {
        tokens: TensorI::new(tokens, &[bu, t])?,
        pad_len: pads,
        gen_mask: TensorF::new(gen_mask, &[bu, g])?,
        old_lp: TensorF::new(old_lp, &[bu, g])?,
        adv,
        ref_lp: TensorF::new(ref_lp, &[bu, g])?,
    })
}

/// One planned `grad` call: the contiguous slice `start..end` of the
/// selected-rollout list, assigned to simulated device `shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroSlot {
    /// Simulated data-parallel device executing this micro-batch.
    pub shard: usize,
    /// First selected-rollout index (inclusive).
    pub start: usize,
    /// Last selected-rollout index (exclusive).
    pub end: usize,
}

/// The update phase's schedule: how the kept rollouts are packed into
/// micro-batches and how the micro-batch sequence is split over shards.
///
/// The packing (`start..end` ranges, global order) depends only on
/// `(m, rows_per_call)` — never on the shard count — which is what makes
/// trained parameters shard-invariant. Shard assignment is contiguous and
/// balanced: micro-batch `k` of `K` runs on shard `k·S / K`.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Simulated device count actually used (capped at the micro-batch
    /// count — an idle shard is not a shard).
    pub shards: usize,
    /// Rows packed per `grad` call.
    pub rows_per_call: usize,
    /// Planned calls in canonical global order.
    pub slots: Vec<MicroSlot>,
}

impl ShardPlan {
    /// Plan an update over `m` kept rollouts: micro-batches of
    /// `rows_per_call` rows split over `shards` devices.
    pub fn new(m: usize, shards: usize, rows_per_call: usize) -> Self {
        let rows_per_call = rows_per_call.max(1);
        let n_calls = m.div_ceil(rows_per_call);
        let shards = shards.max(1).min(n_calls.max(1));
        let slots = (0..n_calls)
            .map(|k| MicroSlot {
                shard: k * shards / n_calls.max(1),
                start: k * rows_per_call,
                end: ((k + 1) * rows_per_call).min(m),
            })
            .collect();
        Self { shards, rows_per_call, slots }
    }

    /// Micro-steps the busiest shard runs (the sequential depth of the
    /// update phase).
    pub fn max_steps_per_shard(&self) -> usize {
        let mut counts = vec![0usize; self.shards];
        for s in &self.slots {
            counts[s.shard] += 1;
        }
        counts.into_iter().max().unwrap_or(0)
    }
}

/// Summary of one update phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateOut {
    /// Mean micro-batch loss weighted by real rows.
    pub loss: f32,
    /// Mean clipped-ratio fraction weighted by real rows.
    pub clip_frac: f32,
    /// Mean KL-to-reference weighted by real rows.
    pub kl: f32,
    /// Physical `grad` calls executed.
    pub micro_steps: usize,
    /// Rollouts the optimizer step trained on.
    pub rollouts_trained: usize,
    /// Simulated device shards the phase ran on (`[update] shards`; all
    /// of them join the collective even when selection kept fewer rows).
    pub shards: usize,
    /// Simulated phase time (zero when nothing was selected):
    /// `max(compute_shard) + allreduce + optimizer`.
    pub sim_update: f64,
    /// Ring all-reduce portion of `sim_update` (zero for one shard).
    pub sim_comm: f64,
    /// Peak rollouts resident per shard in one micro-step (the memory
    /// axis the paper's Fig. 1 ceiling is denominated in).
    pub peak_mem_rollouts: usize,
}

/// Micro-batch packer + sharded gradient-accumulation engine.
///
/// Owns the [`GradAccumulator`] buffer across iterations
/// (allocation-free after the first).
pub struct UpdateEngine {
    accum: GradAccumulator,
}

impl UpdateEngine {
    /// `param_width` is the trainable-vector length (`store.len()`).
    pub fn new(param_width: usize) -> Self {
        Self { accum: GradAccumulator::new(param_width) }
    }

    /// Run one full update phase over `selected` (plus any `replay` rows
    /// drawn from the cross-iteration store) and apply the optimizer.
    /// `cfg` supplies the topology (`[update]`), the loss knobs
    /// (`[algo] kl_coef`, `lr`), the replay clip (`[replay] rho_max`) and
    /// the cost model (`[hwsim]`); the hwsim charge is computed here so
    /// every caller — sync or pipelined — prices the phase identically.
    ///
    /// Replayed rows pack after the fresh rows in canonical order; pass
    /// `&[]` for the no-replay path, which is bit-identical to the
    /// pre-replay engine.
    ///
    /// `stale_floor`: when the staleness-K fleet schedule consumed a
    /// generation batch two or more policy versions old, the **fresh**
    /// rows' behaviour log-probs are floored at `-ln(rho_max)` too —
    /// the same truncated-importance-sampling bound replayed rows always
    /// carry. `None` (staleness <= 1, i.e. both legacy schedules) leaves
    /// fresh rows untouched and the numerics bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        engine: &Engine,
        store: &mut ParamStore,
        base: Option<&[f32]>,
        groups: &[PromptGroup],
        selected: &[SelectedRollout],
        replay: &[StoredRow],
        stale_floor: Option<f64>,
        cfg: &RunConfig,
    ) -> Result<UpdateOut> {
        let bu = engine.meta.config.update_batch;
        let g = engine.meta.gen_len;
        let t = engine.meta.config.seq_len;
        let kl_coef = cfg.algo.kl_coef as f32;
        let rows_per_call = cfg.update.rows_per_call(bu)?;
        let total = selected.len() + replay.len();
        let plan = ShardPlan::new(total, cfg.update.shards, rows_per_call);
        // Truncated importance sampling: floor each replayed token's
        // stored behaviour log-prob at -ln(rho_max), bounding its ratio
        // term by rho_max. Fresh rows are never touched.
        let replay_lp: Vec<Vec<f32>> = replay
            .iter()
            .map(|r| {
                r.record.old_lp.iter().map(|&l| truncate_old_lp(l, cfg.replay.rho_max)).collect()
            })
            .collect();
        // Fresh rows consumed at staleness >= 2 get the same floor (the
        // fleet schedule's off-policy soundness bound); `None` keeps the
        // borrowed originals and every f32 rounding step bit-identical.
        let stale_lp: Option<Vec<Vec<f32>>> = stale_floor.map(|rho| {
            selected
                .iter()
                .map(|sel| {
                    let r = &groups[sel.group_idx].rollouts[sel.rollout_idx];
                    r.old_lp.iter().map(|&l| truncate_old_lp(l, rho)).collect()
                })
                .collect()
        });
        self.accum.reset();
        let mut loss_sum = 0f64;
        let mut clip_sum = 0f64;
        let mut kl_sum = 0f64;
        // Canonical global micro-batch order: the slot sequence is
        // shard-agnostic, so the f32 accumulation below never depends on
        // the simulated topology (the shard-invariance contract).
        for slot in &plan.slots {
            let rows: Vec<PackedRow> = (slot.start..slot.end)
                .map(|i| {
                    if i < selected.len() {
                        let sel = &selected[i];
                        let r = &groups[sel.group_idx].rollouts[sel.rollout_idx];
                        PackedRow {
                            tokens: &r.tokens,
                            pad_len: r.pad_len,
                            gen_mask: &r.gen_mask,
                            old_lp: match &stale_lp {
                                Some(lp) => &lp[i],
                                None => &r.old_lp,
                            },
                            ref_lp: &r.ref_lp,
                            advantage: sel.advantage,
                        }
                    } else {
                        let j = i - selected.len();
                        let r = &replay[j].record;
                        PackedRow {
                            tokens: &r.tokens,
                            pad_len: r.pad_len,
                            gen_mask: &r.gen_mask,
                            old_lp: &replay_lp[j],
                            ref_lp: &r.ref_lp,
                            advantage: replay[j].advantage,
                        }
                    }
                })
                .collect();
            let mb = pack_micro_batch(&rows, bu, g, t)?;
            let out = engine.grad(&store.params, base, &mb, kl_coef)?;
            self.accum.add(&out.grads, bu as f64);
            loss_sum += out.loss as f64 * rows.len() as f64;
            clip_sum += out.clip_frac as f64 * rows.len() as f64;
            kl_sum += out.kl as f64 * rows.len() as f64;
        }
        let micro_steps = self.accum.micro_steps();
        let rollouts_trained = total;
        // an iteration whose selection dropped every group (all groups
        // zero-signal) performs no update and must not be charged for one
        // micro_batch passes through as configured: 0 lets the cost model
        // fall back to the simulated memory ceiling (the toy artifact's
        // B_u is an AOT-shape limitation, not simulated hardware)
        // replayed rows are inside rollouts_trained: they charge full
        // update cost here, and zero inference cost anywhere (their decode
        // was charged in their admission iteration)
        let cost = cfg.hwsim.update_cost(
            rollouts_trained,
            cfg.update.shards,
            cfg.update.micro_batch,
            engine.meta.is_lora(),
        );
        if rollouts_trained > 0 {
            let grads = self.accum.mean(rollouts_trained);
            engine.update(store, &grads, cfg.algo.lr as f32)?;
        }
        Ok(UpdateOut {
            loss: (loss_sum / rollouts_trained.max(1) as f64) as f32,
            clip_frac: (clip_sum / rollouts_trained.max(1) as f64) as f32,
            kl: (kl_sum / rollouts_trained.max(1) as f64) as f32,
            micro_steps,
            rollouts_trained,
            shards: cfg.update.shards,
            sim_update: cost.total,
            sim_comm: cost.comm,
            peak_mem_rollouts: cost.peak_mem_rollouts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_cases, vec_f32};

    /// Simulated `grad` device: the fixed-shape program computes the mean
    /// over its `bu` slots in f32 (padded slots are exact zeros), exactly
    /// like the AOT artifact's batch-mean reduction shape.
    fn device_grad(rows: &[&[f32]], width: usize, bu: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; width];
        for r in rows {
            for (o, v) in out.iter_mut().zip(*r) {
                *o += v;
            }
        }
        for o in out.iter_mut() {
            *o /= bu as f32;
        }
        out
    }

    /// Drive a [`ShardPlan`] through the accumulator the way
    /// [`UpdateEngine::run`] does, over synthetic per-row gradients.
    fn run_plan(plan: &ShardPlan, rows: &[Vec<f32>], width: usize, bu: usize) -> Vec<f32> {
        let mut acc = GradAccumulator::new(width);
        for slot in &plan.slots {
            let chunk: Vec<&[f32]> =
                rows[slot.start..slot.end].iter().map(|r| r.as_slice()).collect();
            let g = device_grad(&chunk, width, bu);
            acc.add(&g, bu as f64);
        }
        acc.mean(rows.len())
    }

    /// The plan covers the selected list contiguously in order, shard ids
    /// are non-decreasing, balanced, and never exceed the micro-batch
    /// count.
    #[test]
    fn shard_plan_partitions_contiguously_and_balanced() {
        for_cases(300, |rng| {
            let m = rng.gen_range_inclusive(1, 97) as usize;
            let shards = rng.gen_range_inclusive(1, 12) as usize;
            let rpc = rng.gen_range_inclusive(1, 16) as usize;
            let plan = ShardPlan::new(m, shards, rpc);
            assert_eq!(plan.slots.len(), m.div_ceil(rpc));
            assert!(plan.shards <= shards && plan.shards <= plan.slots.len());
            let mut next = 0usize;
            let mut last_shard = 0usize;
            let mut counts = vec![0usize; plan.shards];
            for s in &plan.slots {
                assert_eq!(s.start, next, "gap in the packing");
                assert!(s.end > s.start && s.end - s.start <= rpc);
                assert!(s.shard >= last_shard, "shard ids must be non-decreasing");
                assert!(s.shard < plan.shards);
                counts[s.shard] += 1;
                last_shard = s.shard;
                next = s.end;
            }
            assert_eq!(next, m, "plan must cover every kept rollout");
            let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced shard loads {counts:?}");
            assert_eq!(plan.max_steps_per_shard(), *hi);
        });
    }

    /// Satellite proptest: across random (shards, micro_batch, m)
    /// factorizations the sharded accumulation is **bit-identical** to the
    /// monolithic (shards = 1) update — the shard topology never touches
    /// the numeric path.
    #[test]
    fn sharded_accumulation_is_bit_identical_to_monolithic() {
        for_cases(200, |rng| {
            let width = 6;
            let bu = 8usize;
            let m = rng.gen_range_inclusive(1, 64) as usize;
            let shards = rng.gen_range_inclusive(2, 10) as usize;
            let micro_batch = rng.gen_range_inclusive(1, bu as i64) as usize;
            let rows: Vec<Vec<f32>> = (0..m).map(|_| vec_f32(rng, width, -3.0, 3.0)).collect();
            let mono = run_plan(&ShardPlan::new(m, 1, micro_batch), &rows, width, bu);
            let shard = run_plan(&ShardPlan::new(m, shards, micro_batch), &rows, width, bu);
            // bitwise, not approximate: the planned call ranges (and hence
            // every f32 rounding step) must be independent of the shard
            // count
            assert_eq!(mono, shard, "m={m} shards={shards} micro_batch={micro_batch}");
        });
    }

    /// With the default micro-batch (the full `B_u`) the plan replays the
    /// legacy single-shot engine's `chunks(B_u)` packing bit-for-bit —
    /// the golden bridge back to the pre-sharding trainer.
    #[test]
    fn default_micro_batch_replays_legacy_chunks_packing() {
        for_cases(200, |rng| {
            let width = 5;
            let bu = 8usize;
            let m = rng.gen_range_inclusive(1, 50) as usize;
            let shards = rng.gen_range_inclusive(1, 6) as usize;
            let rows: Vec<Vec<f32>> = (0..m).map(|_| vec_f32(rng, width, -2.0, 2.0)).collect();
            // the legacy reference: selected.chunks(bu) + weighted accum
            let mut acc = GradAccumulator::new(width);
            for chunk in rows.chunks(bu) {
                let refs: Vec<&[f32]> = chunk.iter().map(|r| r.as_slice()).collect();
                acc.add(&device_grad(&refs, width, bu), bu as f64);
            }
            let legacy = acc.mean(m);
            let plan = ShardPlan::new(m, shards, bu);
            assert_eq!(run_plan(&plan, &rows, width, bu), legacy);
            // and the plan's physical call count matches the legacy loop
            assert_eq!(plan.slots.len(), m.div_ceil(bu));
        });
    }

    #[test]
    fn plan_for_empty_selection_is_empty() {
        let plan = ShardPlan::new(0, 4, 8);
        assert!(plan.slots.is_empty());
        assert_eq!(plan.max_steps_per_shard(), 0);
    }

    /// The shared packer fills row slots in order and leaves unused slots
    /// exactly at the padded-zero state the grad program treats as inert.
    #[test]
    fn pack_micro_batch_pads_unused_slots_exactly() {
        let (bu, g, t) = (4usize, 3usize, 5usize);
        let tokens = vec![7i32; t];
        let gen_mask = vec![1.0f32; g];
        let old_lp = vec![-0.5f32; g];
        let ref_lp = vec![-0.25f32; g];
        let row = PackedRow {
            tokens: &tokens,
            pad_len: 2,
            gen_mask: &gen_mask,
            old_lp: &old_lp,
            ref_lp: &ref_lp,
            advantage: 1.5,
        };
        let mb = pack_micro_batch(&[row], bu, g, t).unwrap();
        assert_eq!(&mb.tokens.data[..t], &tokens[..]);
        assert!(mb.tokens.data[t..].iter().all(|&x| x == crate::tasks::tokenizer::PAD));
        assert_eq!(mb.pad_len, vec![2, 0, 0, 0]);
        assert_eq!(&mb.old_lp.data[..g], &old_lp[..]);
        assert!(mb.old_lp.data[g..].iter().all(|&x| x == 0.0));
        assert!(mb.gen_mask.data[g..].iter().all(|&x| x == 0.0));
        assert_eq!(mb.adv, vec![1.5, 0.0, 0.0, 0.0]);
    }

    /// Satellite property: a replayed row whose stored behaviour log-probs
    /// equal the current policy's (ratio exactly 1 — zero staleness) packs
    /// into a **bit-identical** micro-batch slot as the same row packed
    /// fresh, so its gradient contribution through the fixed grad program
    /// is identical too. The rho_max floor must stay inactive on log-probs
    /// within the clip bound.
    #[test]
    fn zero_staleness_replay_row_packs_identically_to_fresh() {
        use crate::coordinator::replay::truncate_old_lp;
        for_cases(100, |rng| {
            let (bu, g, t) = (4usize, 6usize, 10usize);
            let rho_max = 1.5 + rng.f64() * 3.0;
            // log-probs within the clip bound: the floor may not touch them
            let bound = -(rho_max as f32).ln();
            let old_lp: Vec<f32> =
                vec_f32(rng, g, bound, 0.0).iter().map(|&v| v.max(bound)).collect();
            let tokens: Vec<i32> = (0..t).map(|i| i as i32).collect();
            let gen_mask = vec![1.0f32; g];
            let ref_lp = vec_f32(rng, g, -2.0, 0.0);
            let adv = rng.f64() as f32 * 2.0 - 1.0;
            let fresh = PackedRow {
                tokens: &tokens,
                pad_len: 1,
                gen_mask: &gen_mask,
                old_lp: &old_lp,
                ref_lp: &ref_lp,
                advantage: adv,
            };
            // the replay path re-derives old_lp through the truncation
            let replay_lp: Vec<f32> =
                old_lp.iter().map(|&l| truncate_old_lp(l, rho_max)).collect();
            let replayed = PackedRow { old_lp: &replay_lp, ..fresh };
            let a = pack_micro_batch(&[fresh], bu, g, t).unwrap();
            let b = pack_micro_batch(&[replayed], bu, g, t).unwrap();
            assert_eq!(a.tokens.data, b.tokens.data);
            assert_eq!(a.pad_len, b.pad_len);
            assert_eq!(a.gen_mask.data, b.gen_mask.data);
            assert_eq!(
                a.old_lp.data, b.old_lp.data,
                "within-bound log-probs must pass through the replay path bitwise"
            );
            assert_eq!(a.ref_lp.data, b.ref_lp.data);
            assert_eq!(a.adv, b.adv);
        });
    }
}
