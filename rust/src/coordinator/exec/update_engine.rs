//! The policy-update phase as a **sharded data-parallel engine**:
//! micro-batch packing, per-shard scheduling, gradient accumulation, a
//! simulated ring all-reduce, and the fused optimizer apply.
//!
//! ## Topology
//!
//! The kept rollouts are packed into micro-batches of
//! `update.micro_batch` rows (default: the profile's full `B_u`), each
//! executed through the fixed-shape AOT `grad` program with unused slots
//! padded (padded rows carry zero advantage and contribute exactly zero
//! gradient). The micro-batch sequence is then split into `update.shards`
//! contiguous device shards ([`ShardPlan`]): shards run their micro-steps
//! in parallel in the cost model, gradients all-reduce once per optimizer
//! step (DDP `no_sync` accumulation semantics), and one AdamW apply
//! finishes the iteration.
//!
//! ## Determinism contract (docs/DETERMINISM.md)
//!
//! Physical execution happens on the host's single PJRT device whatever
//! the simulated topology, and gradients accumulate in **canonical global
//! micro-batch order** into one f32 buffer — the simulated collective is
//! order-stable, unlike a real NCCL ring. Two consequences, both pinned
//! by tests:
//!
//! * **Shard invariance** — trained parameters are bit-identical for any
//!   `update.shards`; the topology only moves simulated cost
//!   (`max(compute_shard) + allreduce + optimizer`, see
//!   [`crate::hwsim::HwModel::update_cost`]).
//! * **Default micro-batch replays the monolith** — with
//!   `micro_batch = 0` the packing is exactly the legacy single-shot
//!   engine's `chunks(B_u)`, so the update is bit-identical to the
//!   pre-sharding trainer. A non-default micro-batch changes which rows
//!   share a device reduction, so its parameters are reproducible but not
//!   comparable across micro-batch sizes.
//!
//! An iteration whose selection dropped every group performs (and is
//! charged) nothing.

use crate::config::RunConfig;
use crate::coordinator::accum::GradAccumulator;
use crate::coordinator::group::{PromptGroup, SelectedRollout};
use crate::runtime::{Engine, MicroBatch, ParamStore, TensorF, TensorI};
use anyhow::Result;

/// One planned `grad` call: the contiguous slice `start..end` of the
/// selected-rollout list, assigned to simulated device `shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroSlot {
    /// Simulated data-parallel device executing this micro-batch.
    pub shard: usize,
    /// First selected-rollout index (inclusive).
    pub start: usize,
    /// Last selected-rollout index (exclusive).
    pub end: usize,
}

/// The update phase's schedule: how the kept rollouts are packed into
/// micro-batches and how the micro-batch sequence is split over shards.
///
/// The packing (`start..end` ranges, global order) depends only on
/// `(m, rows_per_call)` — never on the shard count — which is what makes
/// trained parameters shard-invariant. Shard assignment is contiguous and
/// balanced: micro-batch `k` of `K` runs on shard `k·S / K`.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Simulated device count actually used (capped at the micro-batch
    /// count — an idle shard is not a shard).
    pub shards: usize,
    /// Rows packed per `grad` call.
    pub rows_per_call: usize,
    /// Planned calls in canonical global order.
    pub slots: Vec<MicroSlot>,
}

impl ShardPlan {
    /// Plan an update over `m` kept rollouts: micro-batches of
    /// `rows_per_call` rows split over `shards` devices.
    pub fn new(m: usize, shards: usize, rows_per_call: usize) -> Self {
        let rows_per_call = rows_per_call.max(1);
        let n_calls = m.div_ceil(rows_per_call);
        let shards = shards.max(1).min(n_calls.max(1));
        let slots = (0..n_calls)
            .map(|k| MicroSlot {
                shard: k * shards / n_calls.max(1),
                start: k * rows_per_call,
                end: ((k + 1) * rows_per_call).min(m),
            })
            .collect();
        Self { shards, rows_per_call, slots }
    }

    /// Micro-steps the busiest shard runs (the sequential depth of the
    /// update phase).
    pub fn max_steps_per_shard(&self) -> usize {
        let mut counts = vec![0usize; self.shards];
        for s in &self.slots {
            counts[s.shard] += 1;
        }
        counts.into_iter().max().unwrap_or(0)
    }
}

/// Summary of one update phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateOut {
    /// Mean micro-batch loss weighted by real rows.
    pub loss: f32,
    /// Mean clipped-ratio fraction weighted by real rows.
    pub clip_frac: f32,
    /// Mean KL-to-reference weighted by real rows.
    pub kl: f32,
    /// Physical `grad` calls executed.
    pub micro_steps: usize,
    /// Rollouts the optimizer step trained on.
    pub rollouts_trained: usize,
    /// Simulated device shards the phase ran on (`[update] shards`; all
    /// of them join the collective even when selection kept fewer rows).
    pub shards: usize,
    /// Simulated phase time (zero when nothing was selected):
    /// `max(compute_shard) + allreduce + optimizer`.
    pub sim_update: f64,
    /// Ring all-reduce portion of `sim_update` (zero for one shard).
    pub sim_comm: f64,
    /// Peak rollouts resident per shard in one micro-step (the memory
    /// axis the paper's Fig. 1 ceiling is denominated in).
    pub peak_mem_rollouts: usize,
}

/// Micro-batch packer + sharded gradient-accumulation engine.
///
/// Owns the [`GradAccumulator`] buffer across iterations
/// (allocation-free after the first).
pub struct UpdateEngine {
    accum: GradAccumulator,
}

impl UpdateEngine {
    /// `param_width` is the trainable-vector length (`store.len()`).
    pub fn new(param_width: usize) -> Self {
        Self { accum: GradAccumulator::new(param_width) }
    }

    /// Run one full update phase over `selected` and apply the optimizer.
    /// `cfg` supplies the topology (`[update]`), the loss knobs
    /// (`[algo] kl_coef`, `lr`) and the cost model (`[hwsim]`); the hwsim
    /// charge is computed here so every caller — sync or pipelined —
    /// prices the phase identically.
    pub fn run(
        &mut self,
        engine: &Engine,
        store: &mut ParamStore,
        base: Option<&[f32]>,
        groups: &[PromptGroup],
        selected: &[SelectedRollout],
        cfg: &RunConfig,
    ) -> Result<UpdateOut> {
        let bu = engine.meta.config.update_batch;
        let g = engine.meta.gen_len;
        let t = engine.meta.config.seq_len;
        let kl_coef = cfg.algo.kl_coef as f32;
        let rows_per_call = cfg.update.rows_per_call(bu)?;
        let plan = ShardPlan::new(selected.len(), cfg.update.shards, rows_per_call);
        self.accum.reset();
        let mut loss_sum = 0f64;
        let mut clip_sum = 0f64;
        let mut kl_sum = 0f64;
        // Canonical global micro-batch order: the slot sequence is
        // shard-agnostic, so the f32 accumulation below never depends on
        // the simulated topology (the shard-invariance contract).
        for slot in &plan.slots {
            let chunk = &selected[slot.start..slot.end];
            let mut tokens = vec![crate::tasks::tokenizer::PAD; bu * t];
            let mut pads = vec![0i32; bu];
            let mut gen_mask = vec![0.0f32; bu * g];
            let mut old_lp = vec![0.0f32; bu * g];
            let mut ref_lp = vec![0.0f32; bu * g];
            let mut adv = vec![0.0f32; bu];
            for (b, sel) in chunk.iter().enumerate() {
                let r = &groups[sel.group_idx].rollouts[sel.rollout_idx];
                tokens[b * t..(b + 1) * t].copy_from_slice(&r.tokens);
                pads[b] = r.pad_len;
                gen_mask[b * g..(b + 1) * g].copy_from_slice(&r.gen_mask);
                old_lp[b * g..(b + 1) * g].copy_from_slice(&r.old_lp);
                ref_lp[b * g..(b + 1) * g].copy_from_slice(&r.ref_lp);
                adv[b] = sel.advantage;
            }
            let mb = MicroBatch {
                tokens: TensorI::new(tokens, &[bu, t])?,
                pad_len: pads,
                gen_mask: TensorF::new(gen_mask, &[bu, g])?,
                old_lp: TensorF::new(old_lp, &[bu, g])?,
                adv,
                ref_lp: TensorF::new(ref_lp, &[bu, g])?,
            };
            let out = engine.grad(&store.params, base, &mb, kl_coef)?;
            self.accum.add(&out.grads, bu as f64);
            loss_sum += out.loss as f64 * chunk.len() as f64;
            clip_sum += out.clip_frac as f64 * chunk.len() as f64;
            kl_sum += out.kl as f64 * chunk.len() as f64;
        }
        let micro_steps = self.accum.micro_steps();
        let rollouts_trained = selected.len();
        // an iteration whose selection dropped every group (all groups
        // zero-signal) performs no update and must not be charged for one
        // micro_batch passes through as configured: 0 lets the cost model
        // fall back to the simulated memory ceiling (the toy artifact's
        // B_u is an AOT-shape limitation, not simulated hardware)
        let cost = cfg.hwsim.update_cost(
            rollouts_trained,
            cfg.update.shards,
            cfg.update.micro_batch,
            engine.meta.is_lora(),
        );
        if rollouts_trained > 0 {
            let grads = self.accum.mean(rollouts_trained);
            engine.update(store, &grads, cfg.algo.lr as f32)?;
        }
        Ok(UpdateOut {
            loss: (loss_sum / rollouts_trained.max(1) as f64) as f32,
            clip_frac: (clip_sum / rollouts_trained.max(1) as f64) as f32,
            kl: (kl_sum / rollouts_trained.max(1) as f64) as f32,
            micro_steps,
            rollouts_trained,
            shards: cfg.update.shards,
            sim_update: cost.total,
            sim_comm: cost.comm,
            peak_mem_rollouts: cost.peak_mem_rollouts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{for_cases, vec_f32};

    /// Simulated `grad` device: the fixed-shape program computes the mean
    /// over its `bu` slots in f32 (padded slots are exact zeros), exactly
    /// like the AOT artifact's batch-mean reduction shape.
    fn device_grad(rows: &[&[f32]], width: usize, bu: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; width];
        for r in rows {
            for (o, v) in out.iter_mut().zip(*r) {
                *o += v;
            }
        }
        for o in out.iter_mut() {
            *o /= bu as f32;
        }
        out
    }

    /// Drive a [`ShardPlan`] through the accumulator the way
    /// [`UpdateEngine::run`] does, over synthetic per-row gradients.
    fn run_plan(plan: &ShardPlan, rows: &[Vec<f32>], width: usize, bu: usize) -> Vec<f32> {
        let mut acc = GradAccumulator::new(width);
        for slot in &plan.slots {
            let chunk: Vec<&[f32]> =
                rows[slot.start..slot.end].iter().map(|r| r.as_slice()).collect();
            let g = device_grad(&chunk, width, bu);
            acc.add(&g, bu as f64);
        }
        acc.mean(rows.len())
    }

    /// The plan covers the selected list contiguously in order, shard ids
    /// are non-decreasing, balanced, and never exceed the micro-batch
    /// count.
    #[test]
    fn shard_plan_partitions_contiguously_and_balanced() {
        for_cases(300, |rng| {
            let m = rng.gen_range_inclusive(1, 97) as usize;
            let shards = rng.gen_range_inclusive(1, 12) as usize;
            let rpc = rng.gen_range_inclusive(1, 16) as usize;
            let plan = ShardPlan::new(m, shards, rpc);
            assert_eq!(plan.slots.len(), m.div_ceil(rpc));
            assert!(plan.shards <= shards && plan.shards <= plan.slots.len());
            let mut next = 0usize;
            let mut last_shard = 0usize;
            let mut counts = vec![0usize; plan.shards];
            for s in &plan.slots {
                assert_eq!(s.start, next, "gap in the packing");
                assert!(s.end > s.start && s.end - s.start <= rpc);
                assert!(s.shard >= last_shard, "shard ids must be non-decreasing");
                assert!(s.shard < plan.shards);
                counts[s.shard] += 1;
                last_shard = s.shard;
                next = s.end;
            }
            assert_eq!(next, m, "plan must cover every kept rollout");
            let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced shard loads {counts:?}");
            assert_eq!(plan.max_steps_per_shard(), *hi);
        });
    }

    /// Satellite proptest: across random (shards, micro_batch, m)
    /// factorizations the sharded accumulation is **bit-identical** to the
    /// monolithic (shards = 1) update — the shard topology never touches
    /// the numeric path.
    #[test]
    fn sharded_accumulation_is_bit_identical_to_monolithic() {
        for_cases(200, |rng| {
            let width = 6;
            let bu = 8usize;
            let m = rng.gen_range_inclusive(1, 64) as usize;
            let shards = rng.gen_range_inclusive(2, 10) as usize;
            let micro_batch = rng.gen_range_inclusive(1, bu as i64) as usize;
            let rows: Vec<Vec<f32>> = (0..m).map(|_| vec_f32(rng, width, -3.0, 3.0)).collect();
            let mono = run_plan(&ShardPlan::new(m, 1, micro_batch), &rows, width, bu);
            let shard = run_plan(&ShardPlan::new(m, shards, micro_batch), &rows, width, bu);
            // bitwise, not approximate: the planned call ranges (and hence
            // every f32 rounding step) must be independent of the shard
            // count
            assert_eq!(mono, shard, "m={m} shards={shards} micro_batch={micro_batch}");
        });
    }

    /// With the default micro-batch (the full `B_u`) the plan replays the
    /// legacy single-shot engine's `chunks(B_u)` packing bit-for-bit —
    /// the golden bridge back to the pre-sharding trainer.
    #[test]
    fn default_micro_batch_replays_legacy_chunks_packing() {
        for_cases(200, |rng| {
            let width = 5;
            let bu = 8usize;
            let m = rng.gen_range_inclusive(1, 50) as usize;
            let shards = rng.gen_range_inclusive(1, 6) as usize;
            let rows: Vec<Vec<f32>> = (0..m).map(|_| vec_f32(rng, width, -2.0, 2.0)).collect();
            // the legacy reference: selected.chunks(bu) + weighted accum
            let mut acc = GradAccumulator::new(width);
            for chunk in rows.chunks(bu) {
                let refs: Vec<&[f32]> = chunk.iter().map(|r| r.as_slice()).collect();
                acc.add(&device_grad(&refs, width, bu), bu as f64);
            }
            let legacy = acc.mean(m);
            let plan = ShardPlan::new(m, shards, bu);
            assert_eq!(run_plan(&plan, &rows, width, bu), legacy);
            // and the plan's physical call count matches the legacy loop
            assert_eq!(plan.slots.len(), m.div_ceil(bu));
        });
    }

    #[test]
    fn plan_for_empty_selection_is_empty() {
        let plan = ShardPlan::new(0, 4, 8);
        assert!(plan.slots.is_empty());
        assert_eq!(plan.max_steps_per_shard(), 0);
    }
}
