//! The policy-update phase as a reusable engine: micro-batch packing,
//! gradient accumulation, and the fused optimizer apply.
//!
//! Owns the [`GradAccumulator`] buffer across iterations (allocation-free
//! after the first) and reproduces the seed trainer's update semantics
//! exactly: selected rollouts are packed into fixed-size `B_u`
//! micro-batches, each runs the `grad` artifact, gradients accumulate
//! with padded-slot weighting, and one AdamW apply finishes the
//! iteration. The hwsim charge (`update_time`) is computed here so every
//! caller — sync or pipelined — prices the phase identically, and an
//! iteration whose selection dropped every group performs (and is
//! charged) nothing.

use crate::coordinator::accum::GradAccumulator;
use crate::coordinator::group::{PromptGroup, SelectedRollout};
use crate::hwsim::HwModel;
use crate::runtime::{Engine, MicroBatch, ParamStore, TensorF, TensorI};
use anyhow::Result;

/// Summary of one update phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct UpdateOut {
    pub loss: f32,
    pub clip_frac: f32,
    pub kl: f32,
    pub micro_steps: usize,
    pub rollouts_trained: usize,
    /// Simulated phase time (zero when nothing was selected).
    pub sim_update: f64,
}

/// Micro-batch packer + gradient-accumulation engine.
pub struct UpdateEngine {
    accum: GradAccumulator,
}

impl UpdateEngine {
    /// `param_width` is the trainable-vector length (`store.len()`).
    pub fn new(param_width: usize) -> Self {
        Self { accum: GradAccumulator::new(param_width) }
    }

    /// Run one full update phase over `selected` and apply the optimizer.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        engine: &Engine,
        store: &mut ParamStore,
        base: Option<&[f32]>,
        groups: &[PromptGroup],
        selected: &[SelectedRollout],
        kl_coef: f32,
        lr: f32,
        hw: &HwModel,
    ) -> Result<UpdateOut> {
        let bu = engine.meta.config.update_batch;
        let g = engine.meta.gen_len;
        let t = engine.meta.config.seq_len;
        self.accum.reset();
        let mut loss_sum = 0f64;
        let mut clip_sum = 0f64;
        let mut kl_sum = 0f64;
        for chunk in selected.chunks(bu) {
            let mut tokens = vec![crate::tasks::tokenizer::PAD; bu * t];
            let mut pads = vec![0i32; bu];
            let mut gen_mask = vec![0.0f32; bu * g];
            let mut old_lp = vec![0.0f32; bu * g];
            let mut ref_lp = vec![0.0f32; bu * g];
            let mut adv = vec![0.0f32; bu];
            for (b, sel) in chunk.iter().enumerate() {
                let r = &groups[sel.group_idx].rollouts[sel.rollout_idx];
                tokens[b * t..(b + 1) * t].copy_from_slice(&r.tokens);
                pads[b] = r.pad_len;
                gen_mask[b * g..(b + 1) * g].copy_from_slice(&r.gen_mask);
                old_lp[b * g..(b + 1) * g].copy_from_slice(&r.old_lp);
                ref_lp[b * g..(b + 1) * g].copy_from_slice(&r.ref_lp);
                adv[b] = sel.advantage;
            }
            let mb = MicroBatch {
                tokens: TensorI::new(tokens, &[bu, t])?,
                pad_len: pads,
                gen_mask: TensorF::new(gen_mask, &[bu, g])?,
                old_lp: TensorF::new(old_lp, &[bu, g])?,
                adv,
                ref_lp: TensorF::new(ref_lp, &[bu, g])?,
            };
            let out = engine.grad(&store.params, base, &mb, kl_coef)?;
            self.accum.add(&out.grads, bu as f64);
            loss_sum += out.loss as f64 * chunk.len() as f64;
            clip_sum += out.clip_frac as f64 * chunk.len() as f64;
            kl_sum += out.kl as f64 * chunk.len() as f64;
        }
        let micro_steps = self.accum.micro_steps();
        let rollouts_trained = selected.len();
        // an iteration whose selection dropped every group (all groups
        // zero-signal) performs no update and must not be charged for one
        let sim_update = if rollouts_trained > 0 {
            hw.update_time(rollouts_trained, engine.meta.is_lora())
        } else {
            0.0
        };
        if rollouts_trained > 0 {
            let grads = self.accum.mean(rollouts_trained);
            engine.update(store, &grads, lr)?;
        }
        Ok(UpdateOut {
            loss: (loss_sum / rollouts_trained.max(1) as f64) as f32,
            clip_frac: (clip_sum / rollouts_trained.max(1) as f64) as f32,
            kl: (kl_sum / rollouts_trained.max(1) as f64) as f32,
            micro_steps,
            rollouts_trained,
            sim_update,
        })
    }
}
