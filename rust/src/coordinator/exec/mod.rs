//! The staged training executor.
//!
//! The seed trainer was a single-threaded monolith: groups generated
//! prompt-by-prompt, then the update, strictly back-to-back. This
//! subsystem decomposes one Algorithm-1 step into three composable
//! pieces:
//!
//! * [`RolloutEngine`] — the inference phase on a real thread pool sized
//!   by `hwsim.workers` (per-thread engine replicas), each worker running
//!   the chunked early-exit continuous batcher over its shard of the
//!   iteration's row queue ([`crate::rollout::plan_rows`]).
//! * [`UpdateEngine`] — the sharded data-parallel update: micro-batch
//!   packing over a [`ShardPlan`], gradient accumulation in canonical
//!   global order, a simulated ring all-reduce, and the fused optimizer
//!   apply.
//! * [`TrainLoop`] — the driver composing them under the config-selected
//!   [`Schedule`]:
//!
//! ```text
//!   sync:       gen(t) ──► select(t) ──► update(t) ──► gen(t+1) ──► …
//!
//!   pipelined:  gen(t) ──► select(t) ──► update(t)   ┌ main thread
//!                                  └──► gen(t+1) ……… ┘ rollout pool
//! ```
//!
//! The pipelined schedule prefetches iteration *t+1*'s rollouts (under
//! the pre-update policy θ_t — one-step off-policy, sound because the
//! GRPO loss ratios use the stored behaviour log-probs) while the main
//! thread runs update *t*. The simulated clock then charges
//! `max(inference, update)` for the overlapped portion
//! ([`crate::hwsim::SimClock::advance_hidden`]) and the hidden time is
//! reported per iteration as `sim_overlap_saved`.
//!
//! Both schedules are special cases of the **staleness-K two-fleet
//! model** (`[fleet]`, [`crate::hwsim::FleetSection`]): prefetched
//! generations park in a bounded ready-batch queue and a batch generated
//! under `params(t)` may be consumed by `update(t')` only while
//! `t' − t <= K`. The prefetch depth is
//! `min(K, fleet.queue_capacity)`, the clock's overlap credit accrues
//! per queued batch while one of the `fleet.inference_replicas` decodes
//! it, and a batch consumed at staleness >= 2 has its fresh rows'
//! behaviour log-probs floored at `-ln(replay.rho_max)` (the same
//! truncated-importance-sampling bound the replay path uses). `sync` is
//! exactly K = 0 (empty queue) and `pipelined` is exactly K = 1 with one
//! replica — both reproduce the legacy single-box schedules bit-for-bit
//! (pinned by `rust/tests/fleet_golden.rs`).
//!
//! With `schedule = "sync"` the executor reproduces the sequential
//! reference (`generate_group` prompt-by-prompt) exactly — per-row RNG
//! seeds make rollout streams independent of packing, sharding, chunking
//! and refill order (golden-tested in `rust/tests/exec_golden.rs` and
//! `rust/tests/decode_golden.rs`).

pub mod rollout_engine;
pub mod update_engine;

pub use crate::hwsim::Schedule;
pub use rollout_engine::{GenBatch, PendingGen, RolloutEngine};
pub use update_engine::{pack_micro_batch, MicroSlot, PackedRow, ShardPlan, UpdateEngine, UpdateOut};

use crate::config::{AlgoKind, RunConfig};
use crate::coordinator::advantage::NormMode;
use crate::coordinator::group::{build_update_batch, BatchSelectionStats};
use crate::coordinator::replay::{ReplayStore, StoredRow};
use crate::coordinator::scheduler::BudgetSpec;
use crate::coordinator::select::online::GroupVerdicts;
use crate::coordinator::select::Pipeline;
use crate::hwsim::SimClock;
use crate::reward::RewardWeights;
use crate::rollout::KvPolicy;
use crate::runtime::{Engine, ParamStore};
use crate::tasks::{Split, TaskKind};
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::Arc;

/// Borrowed trainer state one step operates on (the [`TrainLoop`] owns no
/// model state itself — only executor state).
pub struct StepCtx<'a> {
    /// The PJRT engine executing the AOT programs.
    pub engine: &'a Engine,
    /// Live trainable parameters + optimizer state.
    pub store: &'a mut ParamStore,
    /// Frozen full-parameter base (LoRA profiles only).
    pub base: Option<&'a [f32]>,
    /// Reference-policy snapshot (Arc handles — cloning into a GenBatch
    /// shares the vector instead of re-copying it every iteration).
    pub ref_params: Option<Arc<Vec<f32>>>,
    /// Reference-policy adapter snapshot (LoRA profiles with KL).
    pub ref_lora: Option<Arc<Vec<f32>>>,
    /// The run's validated configuration.
    pub cfg: &'a RunConfig,
    /// Rollout-selection pipeline built from `algo.rule`.
    pub pipeline: &'a Pipeline,
    /// Task family generating prompts and verifying answers.
    pub task: TaskKind,
    /// The run's simulated wall clock.
    pub clock: &'a mut SimClock,
    /// Monotone cursor into the train split's prompt stream.
    pub prompt_cursor: &'a mut u64,
}

/// Everything one executed step reports back to the recorder.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    /// Mean total reward over all generated rollouts.
    pub train_reward: f32,
    /// Mean accuracy-component over all generated rollouts.
    pub train_acc: f32,
    /// Mean generated length (tokens incl. EOS).
    pub completion_len: f32,
    /// Mean update loss over trained rollouts.
    pub loss: f32,
    /// Mean clipped-ratio fraction over trained rollouts.
    pub clip_frac: f32,
    /// Mean KL-to-reference over trained rollouts.
    pub kl: f32,
    /// Physical `grad` calls the update executed.
    pub micro_steps: usize,
    /// Rollouts generated this iteration (`prompts × n`).
    pub rollouts_generated: usize,
    /// Rollouts the update trained on (after selection).
    pub rollouts_trained: usize,
    /// Simulated device shards the update was split over.
    pub upd_shards: usize,
    /// Ring all-reduce portion of `sim_update` (0 for one shard).
    pub upd_comm_time: f64,
    /// Peak rollouts resident per shard in one update micro-step.
    pub upd_peak_mem: usize,
    /// Decode-step slots physically executed this iteration (chunked
    /// driver: `B_r × C` per chunk call, post-EOS + filler included).
    pub gen_tokens_decoded: usize,
    /// `gen_tokens_decoded` minus the useful generated tokens.
    pub gen_tokens_wasted: usize,
    /// Decode budget released by online pruning this iteration (per
    /// aborted row: `G` minus its decoded length at the abort boundary).
    pub gen_tokens_pruned: usize,
    /// Rollouts aborted mid-decode by online pruning this iteration.
    pub rows_pruned_online: usize,
    /// Simulated cost of this iteration's inference phase (regardless of
    /// where on the timeline it was charged).
    pub sim_inference: f64,
    /// Simulated cost of this iteration's update phase.
    pub sim_update: f64,
    /// What the clock actually advanced during this step.
    pub sim_step: f64,
    /// Portion of `sim_inference` hidden behind the previous update
    /// (zero under the sync schedule).
    pub sim_overlap_saved: f64,
    /// Aggregated per-group selection telemetry.
    pub sel_stats: BatchSelectionStats,
    /// Reward variance of the selected update batch.
    pub sel_variance: f64,
    /// Stored rows the replay store mixed into this update (0 with
    /// `[replay]` disabled or the store empty).
    pub replay_rows_used: usize,
    /// Rows resident in the replay store after this iteration's
    /// admissions and evictions.
    pub replay_store_size: usize,
    /// Mean staleness in iterations of the rows replayed this update
    /// (0 when none were).
    pub replay_mean_staleness: f64,
    /// Physical prompt-prefill calls the decode drivers executed this
    /// iteration (with `share_prompt_kv`: at most one per admitted group
    /// per shard; without: one per admission event).
    pub prefill_calls: usize,
    /// Refill admissions served from a resident group snapshot instead of
    /// a fresh prefill (0 with `share_prompt_kv` off).
    pub prefill_calls_saved: usize,
    /// Peak bytes resident in the modeled paged KV pool (max over worker
    /// shards — pools are per device).
    pub kv_peak_bytes: u64,
    /// Row-attempt faults the `[faults]` schedule injected this iteration
    /// (0 with the section disabled).
    pub faults_injected: usize,
    /// Physical shard retries submitted this iteration (a partition
    /// detail, like call counts — may vary with the worker count).
    pub shard_retries: usize,
    /// Rollout rows lost after exhausting `faults.max_retries`.
    pub rows_lost: usize,
    /// Simulated time this iteration spent on fault handling: retry
    /// backoff + work wasted by crashed attempts + straggler slowdown.
    /// Included in `sim_inference`.
    pub retry_time: f64,
    /// Extra rollout rows the budget allocator streamed to wide-bracket
    /// groups past the probe quota (0 with `[budget]` disabled).
    pub budget_extra_rows: usize,
    /// Groups whose probe reward bracket was already narrower than
    /// `budget.width_threshold` (0 with `[budget]` disabled).
    pub budget_saturated_groups: usize,
    /// Realized staleness of this iteration's consumed generation batch:
    /// the update version minus the policy version it decoded under
    /// (0 for a fresh inline generation, 1 for the classic pipelined
    /// prefetch, up to `fleet.max_staleness` for deeper queues).
    pub fleet_staleness: usize,
    /// Ready-batch queue depth after this step's prefetch refill.
    pub fleet_queue_depth: usize,
}

/// One prefetched generation parked in the executor's ready-batch queue.
struct QueuedGen {
    /// Iteration this batch generates rollouts for (its consume version).
    iter: usize,
    /// Iteration whose pre-update policy snapshot it decodes under (its
    /// origin version) — realized staleness at consumption is
    /// `iter − born`.
    born: usize,
    /// Simulated update time that elapsed while an inference replica was
    /// decoding this batch — the concurrency credit
    /// [`SimClock::advance_hidden`] hides the inference cost behind.
    overlap: f64,
    /// The in-flight generation handle on the rollout pool.
    pending: PendingGen,
}

/// The schedule-aware driver for one training run.
pub struct TrainLoop {
    /// Inference-phase engine (thread pool of PJRT replicas).
    pub rollout: RolloutEngine,
    /// Sharded policy-update engine.
    pub update: UpdateEngine,
    /// Config-selected phase schedule (sync | pipelined).
    pub schedule: Schedule,
    /// The ready-batch queue: prefetched generations for future
    /// iterations, oldest first. Consumption order is generation history
    /// — never a function of worker partition (docs/DETERMINISM.md).
    queue: VecDeque<QueuedGen>,
    /// Cross-iteration replay store (`[replay]`; stays empty — and costs
    /// nothing — when the section is disabled).
    replay: ReplayStore,
}

impl TrainLoop {
    /// Build the executor for one run: a rollout pool of `workers`
    /// threads over `profile`'s artifacts, an update engine sized for
    /// `param_width` trainable parameters, and the given schedule.
    pub fn new(
        artifacts: PathBuf,
        profile: &str,
        workers: usize,
        schedule: Schedule,
        param_width: usize,
    ) -> Self {
        Self {
            rollout: RolloutEngine::new(artifacts, profile, workers),
            update: UpdateEngine::new(param_width),
            schedule,
            queue: VecDeque::new(),
            replay: ReplayStore::new(),
        }
    }

    /// Read access to the cross-iteration replay store (telemetry and the
    /// determinism goldens in `rust/tests/replay_golden.rs`).
    pub fn replay_store(&self) -> &ReplayStore {
        &self.replay
    }

    // ---- Resume hooks (`coordinator::ckpt`) ---------------------------
    // A crash-consistent resume must reconstruct the two pieces of
    // executor state a fresh TrainLoop lacks: the replay store and the
    // ready-batch queue of in-flight prefetches (each with its origin
    // version and accrued overlap credit).

    /// Replace the replay store wholesale (checkpoint restore).
    pub fn set_replay(&mut self, store: ReplayStore) {
        self.replay = store;
    }

    /// The ready-batch queue at snapshot time, oldest first: for each
    /// queued generation, the iteration it is for, the origin iteration
    /// whose policy it decodes under, the overlap credit it has accrued,
    /// and the behaviour snapshot itself (checkpoint save stores the
    /// snapshot's params so resume can regenerate the exact same
    /// off-policy rollouts).
    pub fn queued_info(&self) -> Vec<(usize, usize, f64, &GenBatch)> {
        self.queue.iter().map(|q| (q.iter, q.born, q.overlap, q.pending.batch())).collect()
    }

    /// Resubmit one queued prefetch from a reconstructed behaviour
    /// snapshot (checkpoint restore; call once per saved entry, in saved
    /// order). The rollout pool regenerates the batch from scratch —
    /// per-row counter RNG makes the streams bit-identical to the ones
    /// the killed run had in flight — and the restored overlap credit
    /// makes the first resumed consumption charge the same hidden time
    /// the uninterrupted run would have.
    pub fn restore_queued(
        &mut self,
        iter: usize,
        born: usize,
        overlap: f64,
        br: usize,
        batch: GenBatch,
    ) -> Result<()> {
        let pending = self.rollout.submit(br, batch)?;
        self.queue.push_back(QueuedGen { iter, born, overlap, pending });
        Ok(())
    }

    /// One full Algorithm-1 step for `iter`. `prefetch_next` permits the
    /// async schedules to keep generating ahead (up to the fleet depth)
    /// while this step's update runs (the driver passes `false` on the
    /// final iteration so the run doesn't pay for an overhanging
    /// generation).
    pub fn step(&mut self, ctx: StepCtx, iter: usize, prefetch_next: bool) -> Result<StepReport> {
        let cfg = ctx.cfg;
        let m = match cfg.algo_kind() {
            AlgoKind::GrpoPods => cfg.algo.m,
            _ => None,
        };

        // ---- Phase 1: rollouts for this iteration ---------------------
        // Redeem the oldest eligible ready batch if it matches `iter` —
        // queue consumption order is generation history, never a choice.
        // A mismatched head (the caller stepped out of order, or retried
        // after an error) invalidates the whole queue: every queued batch
        // is drained and discarded, and the prompt windows their
        // prefetches consumed are handed back to the cursor, so no
        // prompts are silently skipped.
        let mut fleet_staleness = 0usize;
        let mut concurrent = 0.0f64;
        let ready = if self.queue.front().map(|q| q.iter == iter).unwrap_or(false) {
            let q = self.queue.pop_front().expect("head matched above");
            fleet_staleness = iter - q.born;
            concurrent = q.overlap;
            Some(self.rollout.collect(q.pending)?)
        } else {
            if !self.queue.is_empty() {
                let stale = self.queue.len() as u64;
                for q in self.queue.drain(..) {
                    let _ = self.rollout.collect(q.pending);
                }
                *ctx.prompt_cursor = ctx
                    .prompt_cursor
                    .saturating_sub(stale * cfg.run.prompts_per_iter as u64);
            }
            None
        };
        let (groups, gen_stats) = match ready {
            Some((g, s)) => (g, s),
            None => {
                let batch = snapshot_batch(&ctx, iter);
                *ctx.prompt_cursor += cfg.run.prompts_per_iter as u64;
                self.rollout.generate(ctx.engine, batch)?
            }
        };
        let rollouts_generated = gen_stats.rollouts;

        // ---- Graceful-degradation floor -------------------------------
        // Rows lost to exhausted retries leave gaps in their groups; the
        // selector clamps `m` to what survived. Below the configured
        // survivor floor a group's advantage estimate is too degenerate to
        // train on — fail the iteration loudly instead of degrading
        // silently.
        if cfg.faults.enabled {
            let floor = cfg.faults.min_group_survivors;
            for g in &groups {
                if g.rollouts.len() < floor {
                    bail!(
                        "fault degradation floor violated: group (problem {}) kept only {} \
                         of {} rollouts after retries, below faults.min_group_survivors = {} \
                         — raise faults.max_retries or lower the fault rates",
                        g.problem.id,
                        g.rollouts.len(),
                        cfg.algo.n,
                        floor
                    );
                }
            }
        }
        // chunk-granular charging: a chunk runs to completion even when a
        // row finishes mid-chunk, so each rollout's decode time rounds up
        // to the configured chunk size (per-rollout lengths are partition-
        // invariant, unlike the physical call counts). Rollouts aborted by
        // online pruning charge only their actually-decoded tokens.
        let mut gen_lens: Vec<usize> = Vec::new();
        let mut pruned_lens: Vec<usize> = Vec::new();
        for g in &groups {
            for r in &g.rollouts {
                if r.pruned {
                    pruned_lens.push(r.gen_len.max(0) as usize);
                } else {
                    gen_lens.push(r.gen_len.max(0) as usize);
                }
            }
        }
        // With prompt-KV sharing on, the charge prices prefill explicitly
        // (one shared prefill per admitted group instead of one per
        // admission event); otherwise the legacy decode-only models apply,
        // keeping existing cost goldens byte-stable.
        let sim_inference = if cfg.rollout.share_prompt_kv {
            cfg.hwsim.shared_prefill_inference_time(
                &gen_lens,
                &pruned_lens,
                cfg.rollout.decode_chunk,
                gen_stats.prefill_calls,
                ctx.engine.meta.config.prompt_len,
            )
        } else if pruned_lens.is_empty() {
            cfg.hwsim.chunked_inference_time(&gen_lens, cfg.rollout.decode_chunk)
        } else {
            cfg.hwsim.pruned_inference_time(&gen_lens, &pruned_lens, cfg.rollout.decode_chunk)
        };
        // Fault-handling charge, accounted per ROW (backoff per faulted
        // row-attempt, one generation budget of wasted decode per crashed
        // attempt at the solo per-token rate, straggler slowdown as the
        // extra (factor - 1)x time over the afflicted rows' chunk-rounded
        // tokens at the floor rate) — never per physical shard, so it is
        // partition-invariant like the rest of the clock, and exactly
        // zero with `[faults]` disabled.
        let retry_time = gen_stats.fault_backoff_time
            + gen_stats.fault_wasted_tokens as f64 * cfg.hwsim.per_token_time(1)
            + (cfg.faults.straggler_factor - 1.0).max(0.0)
                * gen_stats.straggler_tokens as f64
                * cfg.hwsim.tok_time_floor;
        let sim_inference = sim_inference + retry_time;

        // ---- Phase 2: select + advantages -----------------------------
        let (selected, sel_stats) = build_update_batch(
            &groups,
            ctx.pipeline,
            m,
            cfg.norm_mode(),
            cfg.run.seed,
            iter as u64,
        )?;
        // The online-pruning soundness invariant, enforced at runtime:
        // a rollout aborted mid-decode must never survive selection. If it
        // does, a stage bound lied — fail loudly rather than training on a
        // truncated stream (see docs/DETERMINISM.md).
        for s in &selected {
            if groups[s.group_idx].rollouts[s.rollout_idx].pruned {
                bail!(
                    "online pruning soundness violation: selection kept rollout {} of \
                     group {}, which was aborted mid-decode — a Selector::online_bound \
                     implementation is unsound",
                    s.rollout_idx,
                    s.group_idx
                );
            }
        }
        let sel_rewards: Vec<f32> = selected
            .iter()
            .map(|s| groups[s.group_idx].rollouts[s.rollout_idx].total_reward)
            .collect();
        let sel_idx: Vec<usize> = (0..sel_rewards.len()).collect();
        let sel_variance =
            crate::coordinator::downsample::subset_variance(&sel_rewards, &sel_idx);

        // ---- Phase 2.5: staleness-K prefetch refill -------------------
        // Snapshot the *pre-update* policy θ_t and top the ready-batch
        // queue up to the fleet depth: the rollout pool decodes future
        // iterations with it while the main thread updates to θ_{t+1}.
        // Depth `min(K, queue_capacity)` bounds realized staleness by
        // construction — a batch submitted here is consumed at most
        // `depth` updates after its origin. The first-ahead batch is
        // gated by `prefetch_next` alone (the legacy pipelined contract:
        // the driver passes `false` on the final iteration); deeper
        // slots additionally stop at the run horizon.
        let depth = cfg
            .fleet
            .effective_staleness(self.schedule)
            .min(cfg.fleet.effective_queue_capacity(self.schedule));
        if prefetch_next {
            while self.queue.len() < depth {
                let next_iter = iter + 1 + self.queue.len();
                if !self.queue.is_empty() && next_iter >= cfg.run.iterations {
                    break;
                }
                let batch = snapshot_batch(&ctx, next_iter);
                *ctx.prompt_cursor += cfg.run.prompts_per_iter as u64;
                let br = ctx.engine.meta.config.rollout_batch;
                let pending = self.rollout.submit(br, batch)?;
                self.queue.push_back(QueuedGen {
                    iter: next_iter,
                    born: iter,
                    overlap: 0.0,
                    pending,
                });
            }
        }

        // ---- Phase 2.75: cross-iteration replay -----------------------
        // Draw BEFORE offering this iteration's drops, so every replayed
        // row has staleness >= 1 (replay is cross-iteration by
        // construction). All inputs here — groups, selected, iter — are
        // partition-invariant, so the store's evolution is a pure function
        // of (run_seed, rollout history) whatever the worker count or
        // chunk size (docs/DETERMINISM.md; pinned by replay_golden.rs).
        let mut replayed: Vec<StoredRow> = Vec::new();
        let mut replay_mean_staleness = 0.0f64;
        if cfg.replay.enabled {
            self.replay.evict_stale(iter as u64, cfg.replay.staleness);
            let quota = ReplayStore::quota(selected.len(), cfg.replay.mix_fraction);
            replayed = self.replay.draw(quota);
            if !replayed.is_empty() {
                replay_mean_staleness = replayed
                    .iter()
                    .map(|r| (iter as u64).saturating_sub(r.id.iter) as f64)
                    .sum::<f64>()
                    / replayed.len() as f64;
            }
            self.replay.offer(iter as u64, &groups, &selected, &cfg.replay);
        }

        // ---- Phase 3: sharded micro-batched update --------------------
        // Replayed rows pack after the fresh rows: they charge full update
        // cost (inside upd.rollouts_trained) but zero inference time —
        // gen_lens above only ever sees freshly decoded rollouts.
        // Staleness-K off-policy soundness: a batch consumed >= 2 policy
        // versions after its origin gets the truncated-importance-
        // sampling floor on its fresh rows too — the same `rho_max` bound
        // that makes replayed rows sound. Staleness 0 and 1 pass `None`,
        // keeping the legacy schedules' numerics bit-identical.
        let stale_floor = if fleet_staleness >= 2 { Some(cfg.replay.rho_max) } else { None };
        let upd = self.update.run(
            ctx.engine,
            ctx.store,
            ctx.base,
            &groups,
            &selected,
            &replayed,
            stale_floor,
            cfg,
        )?;

        // ---- Clock: overlap-aware charging ----------------------------
        // A redeemed ready batch's inference ran concurrently with the
        // updates that elapsed while a replica decoded it; only its
        // overhang advances the clock. Then the overlap credit accrues to
        // the queued batches currently held by one of the
        // `fleet.inference_replicas` (the front of the queue) — deeper
        // entries wait for a free replica and accrue nothing yet.
        let charged_inference = ctx.clock.advance_hidden(sim_inference, concurrent);
        ctx.clock.advance(upd.sim_update);
        let replicas = cfg.fleet.inference_replicas.max(1);
        for q in self.queue.iter_mut().take(replicas) {
            q.overlap += upd.sim_update;
        }

        let n_groups = groups.len().max(1) as f32;
        Ok(StepReport {
            train_reward: groups.iter().map(|gr| gr.mean_reward()).sum::<f32>() / n_groups,
            train_acc: groups.iter().map(|gr| gr.mean_accuracy()).sum::<f32>() / n_groups,
            completion_len: groups.iter().map(|gr| gr.mean_gen_len()).sum::<f32>() / n_groups,
            loss: upd.loss,
            clip_frac: upd.clip_frac,
            kl: upd.kl,
            micro_steps: upd.micro_steps,
            rollouts_generated,
            rollouts_trained: upd.rollouts_trained,
            upd_shards: upd.shards,
            upd_comm_time: upd.sim_comm,
            upd_peak_mem: upd.peak_mem_rollouts,
            gen_tokens_decoded: gen_stats.gen_tokens_decoded,
            gen_tokens_wasted: gen_stats.gen_tokens_wasted,
            gen_tokens_pruned: gen_stats.gen_tokens_pruned,
            rows_pruned_online: gen_stats.rows_pruned,
            sim_inference,
            sim_update: upd.sim_update,
            sim_step: charged_inference + upd.sim_update,
            sim_overlap_saved: sim_inference - charged_inference,
            sel_stats,
            sel_variance,
            replay_rows_used: replayed.len(),
            replay_store_size: self.replay.len(),
            replay_mean_staleness,
            prefill_calls: gen_stats.prefill_calls,
            prefill_calls_saved: gen_stats.prefill_calls_saved,
            kv_peak_bytes: gen_stats.kv_peak_bytes,
            faults_injected: gen_stats.faults_injected,
            shard_retries: gen_stats.shard_retries,
            rows_lost: gen_stats.rows_lost,
            retry_time,
            budget_extra_rows: gen_stats.budget_extra_rows,
            budget_saturated_groups: gen_stats.budget_saturated_groups,
            fleet_staleness,
            fleet_queue_depth: self.queue.len(),
        })
    }
}

/// Snapshot everything generation for `iter` needs from the live trainer
/// state. The parameter clones are what make the pipelined overlap sound:
/// the pool decodes against frozen copies while the optimizer mutates the
/// store. The inline sync path pays one extra params copy per iteration,
/// which is noise next to the per-call literal upload the engine already
/// does (`lit_f32` copies the full vector on every rollout call).
///
/// When `[rollout] online_prune` is on for a PODS run (a selection target
/// `m` exists and advantages normalize on the selected subset), the
/// snapshot also seeds one [`GroupVerdicts`] aggregator for the batch —
/// fresh per iteration, shared by every worker shard.
fn snapshot_batch(ctx: &StepCtx, iter: usize) -> GenBatch {
    let full: &[f32] = match ctx.base {
        Some(b) => b,
        None => &ctx.store.params,
    };
    let lora: Option<&[f32]> =
        if ctx.engine.meta.is_lora() { Some(&ctx.store.params) } else { None };
    build_gen_batch(
        ctx.cfg,
        ctx.engine,
        ctx.pipeline,
        ctx.task,
        ctx.ref_params.clone(),
        ctx.ref_lora.clone(),
        Arc::new(full.to_vec()),
        lora.map(|l| Arc::new(l.to_vec())),
        *ctx.prompt_cursor,
        iter,
    )
}

/// The shared core of [`snapshot_batch`] and checkpoint restore
/// (`coordinator::ckpt` rebuilds an in-flight prefetch from saved
/// behaviour parameters): one construction site for the online-verdict
/// gate, the KV policy and the fault plan guarantees both paths produce
/// identical batches for identical inputs.
#[allow(clippy::too_many_arguments)]
pub fn build_gen_batch(
    cfg: &RunConfig,
    engine: &Engine,
    pipeline: &Pipeline,
    task: TaskKind,
    ref_params: Option<Arc<Vec<f32>>>,
    ref_lora: Option<Arc<Vec<f32>>>,
    params: Arc<Vec<f32>>,
    lora: Option<Arc<Vec<f32>>>,
    cursor: u64,
    iter: usize,
) -> GenBatch {
    let problems = task.batch(Split::Train, cursor, cfg.run.prompts_per_iter);
    let weights = RewardWeights::default();
    let m = match cfg.algo_kind() {
        AlgoKind::GrpoPods => cfg.algo.m,
        _ => None,
    };
    // `adv_norm = "before"` reads every rollout's reward (including
    // dropped ones), which a truncated stream would perturb — config
    // validation rejects the combination, and this gate backstops
    // programmatically-built configs.
    let budget = BudgetSpec::from_config(cfg);
    // Under a budget the verdict groups start at the probe quota; the
    // rollout engine grows them (`GroupVerdicts::grow_group`) when the
    // allocator streams extra rows after the probe wave.
    let n0 = budget.map(|b| b.n_probe).unwrap_or(cfg.algo.n);
    let online = match m {
        Some(m) if cfg.rollout.online_prune && cfg.norm_mode() == NormMode::After => {
            Some(Arc::new(GroupVerdicts::new(pipeline, problems.len(), n0, m, &weights)))
        }
        _ => None,
    };
    GenBatch {
        params,
        lora,
        ref_params,
        ref_lora,
        problems: Arc::new(problems),
        n: cfg.algo.n,
        temperature: cfg.algo.temperature as f32,
        run_seed: cfg.run.seed,
        iter: iter as u64,
        task,
        weights,
        decode_chunk: cfg.rollout.decode_chunk,
        refill: cfg.rollout.refill,
        online,
        kv: KvPolicy::from_model(
            &cfg.hwsim,
            cfg.rollout.share_prompt_kv,
            engine.meta.config.prompt_len,
            engine.meta.config.seq_len - engine.meta.config.prompt_len,
        ),
        faults: cfg.faults.plan(cfg.run.seed),
        budget,
    }
}
