//! Cross-iteration rollout replay: staleness-bounded reuse of dropped
//! rollouts (`[replay]`).
//!
//! PODS discards `n - m` rollouts per prompt per iteration after paying
//! their full decode cost. The [`ReplayStore`] sits **behind** the
//! selection pipeline: rollouts the pipeline drops are offered to the
//! store, scored by how much reward signal the kept subset lost
//! ([`bracket_distance`] to the kept rewards — the same bracket math the
//! online pruner reasons with), and retained under a per-prompt capacity
//! and a staleness bound in iterations. Later updates draw stored rows
//! back into the update batch (`[replay] mix_fraction` of the fresh
//! update size); replayed rows carry their stored behaviour log-probs, so
//! the GRPO ratio term `exp(lp - old_lp)` applies the importance-sampling
//! correction, truncated by flooring the stored log-probs at
//! `-ln(rho_max)` ([`truncate_old_lp`]).
//!
//! **Determinism contract** (pinned by `rust/tests/replay_golden.rs` and
//! documented in `docs/DETERMINISM.md`): the store's contents — and hence
//! the rows eligible at iteration `t` — are a pure function of
//! `(run_seed, history)`. Every admission input (group rewards, selection
//! output, prompt ids, iteration number) is itself invariant to worker
//! count, chunk size and schedule, offers are canonicalized by sorting on
//! the stable [`RowId`], and eviction/draw orders are total orders with
//! `RowId` tie-breaks. Replayed rows charge **zero inference time** (they
//! were decoded in their admission iteration) but **full update cost**.

use crate::config::ReplaySection;
use crate::coordinator::advantage::SIGMA_EPS;
use crate::coordinator::group::{PromptGroup, RolloutRecord, SelectedRollout};
use crate::coordinator::select::online::bracket_distance;
use crate::rollout::replay_handoff_eligible;

/// Stable identity of a stored row: the coordinates that name a rollout
/// independently of worker-pool partitioning, chunk size and schedule.
/// The derived lexicographic order (`iter`, then `prompt_id`, then
/// `rollout_idx`) is the tie-break of every deterministic ordering in
/// this module.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RowId {
    /// Iteration the rollout was generated (and admitted) in.
    pub iter: u64,
    /// `Problem::id` of the rollout's prompt.
    pub prompt_id: u64,
    /// Rollout index within its prompt group.
    pub rollout_idx: u32,
}

/// One admitted rollout with everything a later update needs.
#[derive(Debug, Clone)]
pub struct StoredRow {
    /// Stable identity (also the eviction/draw tie-break).
    pub id: RowId,
    /// Admission score: [`bracket_distance`] from the row's reward to the
    /// kept subset's rewards at admission time. Higher = the selection
    /// dropped more signal by excluding this row.
    pub score: f32,
    /// Advantage normalized against the admission iteration's kept-subset
    /// statistics (the `adv_norm = "after"` convention).
    pub advantage: f32,
    /// The full update-ready rollout payload (tokens, `old_lp`, masks).
    pub record: RolloutRecord,
}

/// Staleness-bounded store of dropped rollouts, keyed by prompt.
///
/// All mutating operations keep `rows` sorted by [`RowId`], so the store's
/// state admits a canonical representation whatever order history was
/// replayed in.
#[derive(Debug, Default)]
pub struct ReplayStore {
    rows: Vec<StoredRow>,
}

impl ReplayStore {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a store from serialized rows (checkpoint/resume). Rows are
    /// re-sorted into the canonical `RowId` order, so the restored store
    /// is state-identical to the one that was saved whatever order the
    /// checkpoint happened to serialize.
    pub fn from_rows(mut rows: Vec<StoredRow>) -> Self {
        rows.sort_by_key(|r| r.id);
        Self { rows }
    }

    /// Stored rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Current contents in canonical (`RowId`-ascending) order.
    pub fn contents(&self) -> &[StoredRow] {
        &self.rows
    }

    /// Evict every row outside the staleness window at iteration `iter`:
    /// a row admitted at `s` is eligible while `iter - s <= staleness`.
    pub fn evict_stale(&mut self, iter: u64, staleness: usize) {
        self.rows.retain(|r| iter.saturating_sub(r.id.iter) <= staleness as u64);
    }

    /// Offer one iteration's dropped rollouts.
    ///
    /// For each group with a non-empty kept subset, every dropped,
    /// handoff-eligible rollout (see
    /// [`crate::rollout::replay_handoff_eligible`]) is admitted with its
    /// bracket-distance score and kept-subset-normalized advantage; then
    /// each prompt is trimmed back to `cfg.capacity_per_prompt` rows by
    /// the deterministic eviction order **staleness-then-score** (stalest
    /// evicted first, then lowest score; on full ties the smaller
    /// [`RowId`] is preferred and survives).
    ///
    /// Groups whose selection came back empty are skipped: there is no
    /// kept subset to score or normalize against.
    pub fn offer(
        &mut self,
        iter: u64,
        groups: &[PromptGroup],
        selected: &[SelectedRollout],
        cfg: &ReplaySection,
    ) {
        for (gi, group) in groups.iter().enumerate() {
            let kept: Vec<usize> = selected
                .iter()
                .filter(|s| s.group_idx == gi)
                .map(|s| s.rollout_idx)
                .collect();
            if kept.is_empty() {
                continue;
            }
            let kept_rewards: Vec<f32> =
                kept.iter().map(|&ri| group.rollouts[ri].total_reward).collect();
            // kept-subset statistics, same convention as subset_advantages
            // (population std in f64, SIGMA_EPS floor)
            let kn = kept_rewards.len() as f64;
            let mean = kept_rewards.iter().map(|&r| r as f64).sum::<f64>() / kn;
            let var = kept_rewards.iter().map(|&r| (r as f64 - mean).powi(2)).sum::<f64>() / kn;
            let std = var.sqrt();
            for (ri, record) in group.rollouts.iter().enumerate() {
                if kept.contains(&ri) || !replay_handoff_eligible(record) {
                    continue;
                }
                self.rows.push(StoredRow {
                    id: RowId {
                        iter,
                        prompt_id: group.problem.id,
                        rollout_idx: ri as u32,
                    },
                    score: bracket_distance(record.total_reward, &kept_rewards),
                    advantage: ((record.total_reward as f64 - mean) / (std + SIGMA_EPS)) as f32,
                    record: record.clone(),
                });
            }
        }
        self.enforce_capacity(cfg.capacity_per_prompt);
        self.rows.sort_by_key(|r| r.id);
    }

    /// Trim every prompt back to `capacity` rows, evicting in the order
    /// staleness-then-score with `RowId` ties: keep-priority sorts fresher
    /// first, then higher score, then smaller id.
    fn enforce_capacity(&mut self, capacity: usize) {
        let mut by_prompt: std::collections::BTreeMap<u64, Vec<StoredRow>> = Default::default();
        for row in self.rows.drain(..) {
            by_prompt.entry(row.id.prompt_id).or_default().push(row);
        }
        for rows in by_prompt.values_mut() {
            rows.sort_by(|a, b| {
                b.id.iter
                    .cmp(&a.id.iter)
                    .then(b.score.total_cmp(&a.score))
                    .then(a.id.cmp(&b.id))
            });
            rows.truncate(capacity);
            self.rows.append(rows);
        }
    }

    /// Draw up to `quota` rows for one update, consuming them: highest
    /// score first, ties by [`RowId`]. The returned order is the order the
    /// rows are packed in, so it is part of the determinism contract.
    pub fn draw(&mut self, quota: usize) -> Vec<StoredRow> {
        if quota == 0 || self.rows.is_empty() {
            return Vec::new();
        }
        let mut order: Vec<usize> = (0..self.rows.len()).collect();
        order.sort_by(|&a, &b| {
            self.rows[b]
                .score
                .total_cmp(&self.rows[a].score)
                .then(self.rows[a].id.cmp(&self.rows[b].id))
        });
        order.truncate(quota);
        let mut take: Vec<bool> = vec![false; self.rows.len()];
        for &i in &order {
            take[i] = true;
        }
        let mut drawn: Vec<Option<StoredRow>> = Vec::with_capacity(order.len());
        let mut kept = Vec::with_capacity(self.rows.len() - order.len());
        let mut slots: std::collections::BTreeMap<usize, usize> = Default::default();
        for (pos, &i) in order.iter().enumerate() {
            slots.insert(i, pos);
            drawn.push(None);
        }
        for (i, row) in self.rows.drain(..).enumerate() {
            if take[i] {
                drawn[slots[&i]] = Some(row);
            } else {
                kept.push(row);
            }
        }
        self.rows = kept;
        drawn.into_iter().flatten().collect()
    }

    /// Replay quota per update: `floor(mix_fraction * fresh_rows)`.
    pub fn quota(fresh_rows: usize, mix_fraction: f64) -> usize {
        (mix_fraction.max(0.0) * fresh_rows as f64).floor() as usize
    }
}

/// Truncated importance-sampling floor on a stored per-token behaviour
/// log-prob: `max(old_lp, -ln(rho_max))`.
///
/// Current-policy log-probs are `<= 0`, so after flooring, every replayed
/// token's ratio `exp(lp - old_lp)` is bounded by
/// `exp(0 + ln(rho_max)) = rho_max`. The floor is inactive
/// (`old_lp` unchanged, ratio term untouched) whenever
/// `old_lp >= -ln(rho_max)` — in particular a zero-staleness row with
/// ratio exactly 1 contributes exactly like a fresh row.
pub fn truncate_old_lp(old_lp: f32, rho_max: f64) -> f32 {
    old_lp.max(-(rho_max.max(1.0) as f32).ln())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::advantage::NormMode;
    use crate::coordinator::group::build_update_batch;
    use crate::coordinator::select::Pipeline;
    use crate::util::prop::{for_cases, vec_f32};

    fn cfg(capacity: usize, staleness: usize) -> ReplaySection {
        ReplaySection {
            enabled: true,
            mix_fraction: 0.25,
            staleness,
            capacity_per_prompt: capacity,
            rho_max: 2.0,
        }
    }

    fn select(groups: &[PromptGroup], m: usize) -> Vec<SelectedRollout> {
        let p = Pipeline::parse_default("max_variance").unwrap();
        build_update_batch(groups, &p, Some(m), NormMode::After, 0, 0).unwrap().0
    }

    #[test]
    fn admits_dropped_rows_with_bracket_scores() {
        let groups = vec![PromptGroup::synthetic(0, &[0.0, 1.0, 2.0, 3.0], None)];
        let selected = select(&groups, 2); // max_variance keeps {0, 3}
        let mut store = ReplayStore::new();
        store.offer(1, &groups, &selected, &cfg(8, 2));
        assert_eq!(store.len(), 2);
        let ids: Vec<u32> = store.contents().iter().map(|r| r.id.rollout_idx).collect();
        assert_eq!(ids, vec![1, 2]);
        // rewards 1 and 2 are each 1.0 from the nearest kept reward (0 / 3)
        for row in store.contents() {
            assert!((row.score - 1.0).abs() < 1e-6, "score {}", row.score);
            assert_eq!(row.id.iter, 1);
            assert_eq!(row.id.prompt_id, groups[0].problem.id);
        }
        // kept subset {0, 3}: mean 1.5, std 1.5 -> advantages of 1, 2 are
        // -1/3 and +1/3
        assert!((store.contents()[0].advantage + 1.0 / 3.0).abs() < 1e-4);
        assert!((store.contents()[1].advantage - 1.0 / 3.0).abs() < 1e-4);
    }

    #[test]
    fn skips_pruned_rows_and_empty_kept_groups() {
        let mut groups = vec![
            PromptGroup::synthetic(0, &[0.0, 1.0, 2.0, 3.0], None),
            PromptGroup::synthetic(1, &[1.0, 1.5, 2.5, 4.0], None),
        ];
        groups[0].rollouts[1].pruned = true;
        // group 1 contributes nothing to `selected` (simulates a dropped
        // group): none of its rows may be admitted
        let selected: Vec<SelectedRollout> = select(&groups, 2)
            .into_iter()
            .filter(|s| s.group_idx == 0)
            .collect();
        let mut store = ReplayStore::new();
        store.offer(0, &groups, &selected, &cfg(8, 2));
        let ids: Vec<(u64, u32)> =
            store.contents().iter().map(|r| (r.id.prompt_id, r.id.rollout_idx)).collect();
        assert_eq!(ids, vec![(groups[0].problem.id, 2)], "only group 0's unpruned drop");
    }

    #[test]
    fn staleness_eviction_is_a_sliding_window() {
        let groups = vec![PromptGroup::synthetic(0, &[0.0, 1.0, 2.0, 3.0], None)];
        let mut store = ReplayStore::new();
        for it in 0..4u64 {
            store.offer(it, &groups, &select(&groups, 2), &cfg(64, 2));
        }
        assert_eq!(store.len(), 8);
        store.evict_stale(4, 2);
        let iters: Vec<u64> = store.contents().iter().map(|r| r.id.iter).collect();
        assert_eq!(iters, vec![2, 2, 3, 3], "window [iter-2, iter] kept");
        store.evict_stale(10, 2);
        assert!(store.is_empty());
    }

    /// Capacity eviction order is the golden contract: stalest evicted
    /// first, then lowest score, ties by RowId.
    #[test]
    fn capacity_eviction_is_staleness_then_score_with_id_ties() {
        // one prompt; two iterations of offers with distinct score spreads
        let g_wide = vec![PromptGroup::synthetic(7, &[0.0, 0.5, 2.5, 3.0], None)];
        let g_tight = vec![PromptGroup::synthetic(7, &[0.0, 1.4, 1.6, 3.0], None)];
        let mut store = ReplayStore::new();
        // iter 0 drops rewards {0.5, 2.5}: scores 0.5 each
        store.offer(0, &g_wide, &select(&g_wide, 2), &cfg(64, 8));
        // iter 1 drops rewards {1.4, 1.6}: scores 1.4 each
        store.offer(1, &g_tight, &select(&g_tight, 2), &cfg(64, 8));
        assert_eq!(store.len(), 4);
        // capacity 3: the stalest admissions (iter 0) are evicted first,
        // lowest score first; on a full tie the smaller RowId is preferred
        // (kept), so row (iter 0, idx 2) goes
        let mut tight = store;
        tight.enforce_capacity(3);
        tight.rows.sort_by_key(|r| r.id);
        let kept: Vec<(u64, u32)> =
            tight.contents().iter().map(|r| (r.id.iter, r.id.rollout_idx)).collect();
        assert_eq!(kept, vec![(0, 1), (1, 1), (1, 2)]);
        // capacity 1: only the freshest-iteration, highest-score,
        // smallest-id row survives
        tight.enforce_capacity(1);
        let kept: Vec<(u64, u32)> =
            tight.contents().iter().map(|r| (r.id.iter, r.id.rollout_idx)).collect();
        assert_eq!(kept, vec![(1, 1)]);
    }

    /// Draw consumes highest-score-first with RowId ties, and the store
    /// keeps the rest.
    #[test]
    fn draw_order_is_score_then_id_and_consumes() {
        let g_wide = vec![PromptGroup::synthetic(3, &[0.0, 0.5, 2.5, 3.0], None)];
        let g_tight = vec![PromptGroup::synthetic(3, &[0.0, 1.4, 1.6, 3.0], None)];
        let mut store = ReplayStore::new();
        store.offer(0, &g_wide, &select(&g_wide, 2), &cfg(64, 8));
        store.offer(1, &g_tight, &select(&g_tight, 2), &cfg(64, 8));
        let drawn = store.draw(3);
        let got: Vec<(u64, u32)> = drawn.iter().map(|r| (r.id.iter, r.id.rollout_idx)).collect();
        // scores: iter-1 rows 1.4 each, iter-0 rows 0.5 each; RowId breaks
        // both ties ascending
        assert_eq!(got, vec![(1, 1), (1, 2), (0, 1)]);
        assert_eq!(store.len(), 1);
        assert_eq!(store.contents()[0].id, RowId { iter: 0, prompt_id: 3, rollout_idx: 2 });
        // drawing more than remains drains without panicking
        assert_eq!(store.draw(10).len(), 1);
        assert!(store.is_empty());
        assert!(store.draw(4).is_empty());
    }

    /// Store contents are invariant to the order groups are offered in —
    /// the group-order axis of the (run_seed, history) purity contract.
    #[test]
    fn store_contents_invariant_to_group_order() {
        for_cases(60, |rng| {
            let r0 = vec_f32(rng, 8, 0.0, 3.0);
            let r1 = vec_f32(rng, 8, 0.0, 3.0);
            let a = PromptGroup::synthetic(21, &r0, None);
            let b = PromptGroup::synthetic(22, &r1, None);
            let run = |groups: Vec<PromptGroup>| {
                let selected = select(&groups, 3);
                let mut store = ReplayStore::new();
                store.offer(5, &groups, &selected, &cfg(4, 2));
                let sig: Vec<(u64, u32, u32, u32)> = store
                    .contents()
                    .iter()
                    .map(|r| {
                        (r.id.prompt_id, r.id.rollout_idx, r.score.to_bits(), r.advantage.to_bits())
                    })
                    .collect();
                let drawn: Vec<(u64, u32)> =
                    store.draw(3).iter().map(|r| (r.id.prompt_id, r.id.rollout_idx)).collect();
                (sig, drawn)
            };
            let ab = run(vec![a.clone(), b.clone()]);
            let ba = run(vec![b, a]);
            assert_eq!(ab, ba, "store state must not depend on group order");
        });
    }

    #[test]
    fn quota_is_floor_of_mix_fraction() {
        assert_eq!(ReplayStore::quota(16, 0.25), 4);
        assert_eq!(ReplayStore::quota(15, 0.25), 3);
        assert_eq!(ReplayStore::quota(16, 0.0), 0);
        assert_eq!(ReplayStore::quota(0, 0.5), 0);
        assert_eq!(ReplayStore::quota(3, 1.0), 3);
    }

    /// Satellite: `rho_max` truncation is monotone in the clip bound —
    /// a looser bound never truncates more — and inactive on log-probs
    /// already within the bound (a ratio-1 row is untouched).
    #[test]
    fn rho_truncation_monotone_in_clip_bound() {
        for_cases(300, |rng| {
            let lp = -(rng.f64() * 8.0) as f32; // log-probs are <= 0
            let a = 1.0 + rng.f64() * 4.0;
            let b = a + rng.f64() * 4.0; // b >= a >= 1
            let ta = truncate_old_lp(lp, a);
            let tb = truncate_old_lp(lp, b);
            // looser clip -> lower (or equal) floor -> old_lp closer to the
            // stored value; the implied per-token ratio cap exp(-t) grows
            assert!(tb <= ta, "truncation must be monotone: {tb} > {ta}");
            assert!(ta >= lp && tb >= lp, "flooring never lowers old_lp");
            // the bound actually binds: ratio exp(lp_new - t) <= rho_max
            // for any current-policy lp_new <= 0
            assert!((-ta).exp() <= a as f32 * (1.0 + 1e-5));
            // inactive inside the bound
            if lp >= -(a as f32).ln() {
                assert_eq!(ta, lp, "within-bound log-probs must pass through");
            }
        });
        // rho_max < 1 clamps to 1 (never truncates a ratio-1 row to below 1)
        assert_eq!(truncate_old_lp(-0.0, 0.5), 0.0);
    }
}
