//! End-to-end composition proof at ~100M parameters (DESIGN.md §2):
//! loads the `big` profile (d=768, L=14, H=12 — 99M params), runs SFT
//! warm-up steps and full GRPO-PODS training iterations, logging the loss
//! curve — proving every layer (Pallas kernels -> JAX AOT -> PJRT runtime
//! -> Rust coordinator) composes at LLM-like scale.
//!
//! Requires `make artifacts-big`. Runtime is minutes/step on one CPU core,
//! so the default budget is small:
//!
//! ```sh
//! make artifacts-big
//! cargo run --release --example e2e_100m -- [--sft-steps N] [--rl-iters N]
//! ```

use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;

fn arg(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let artifacts = pods::default_artifacts_dir();
    if !artifacts.join("big/meta.json").exists() {
        eprintln!("big profile missing — run `make artifacts-big` first");
        std::process::exit(1);
    }
    let sft_steps = arg("--sft-steps", 3);
    let rl_iters = arg("--rl-iters", 2);
    let cfg = CfgBuilder {
        name: "e2e_100m".into(),
        profile: "big".into(),
        task: "arith".into(),
        iterations: rl_iters,
        prompts_per_iter: 1,
        eval_every: rl_iters.max(1),
        eval_problems: 4,
        kind: "pods".into(),
        n: 8,
        m: Some(4),
        lr: 1e-4,
        sft_steps,
        sft_lr: 1e-3,
        out_dir: "results".into(),
        ..Default::default()
    }
    .build()?;
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(&artifacts, cfg)?;
    println!(
        "policy: {} parameters ({} trainable)",
        trainer.engine.meta.param_count, trainer.engine.meta.trainable_count
    );
    trainer.run()?;
    for row in &trainer.recorder.iters {
        println!(
            "iter {:>3}: loss {:+.4} trainR {:.2} clip {:.3} ({} rollouts -> {} trained)",
            row.iter, row.loss, row.train_reward, row.clip_frac,
            row.rollouts_generated, row.rollouts_trained
        );
    }
    println!("e2e_100m OK in {:.1}s real", t0.elapsed().as_secs_f64());
    Ok(())
}
