//! (n, m) sweep — the Fig. 4 deployment-guidance study as an example:
//! how rollout size n (diminishing returns) and update size m (robust
//! until very small) affect GRPO-PODS.
//!
//! ```sh
//! cargo run --release --example sweep_nm -- [--quick]
//! ```

use pods::exp::{fig4, Scale};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { Scale::Quick } else { Scale::Full };
    fig4::run(&pods::default_artifacts_dir(), scale, "results")?;
    println!("rows: results/fig4.csv");
    Ok(())
}
