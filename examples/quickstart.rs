//! Quickstart: the PODS public API in ~60 lines.
//!
//! Loads the `base` artifact profile, initializes a policy, runs three
//! GRPO-PODS training iterations on the synthetic GSM8K-like task, and
//! evaluates — demonstrating the full inference -> verify -> down-sample ->
//! update loop. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use pods::coordinator::downsample::{max_variance, subset_variance};
use pods::coordinator::scheduler::Trainer;
use pods::exp::CfgBuilder;

fn main() -> anyhow::Result<()> {
    let artifacts = pods::default_artifacts_dir();

    // 1. The core algorithm, standalone: Algorithm 2 in O(n log n).
    let rewards = vec![0.0f32, 3.0, 1.0, 2.0, 3.0, 0.0, 1.0, 2.0];
    let picked = max_variance(&rewards, 4)?;
    println!(
        "max-variance subset of {rewards:?} (m=4): {picked:?} (variance {:.3})",
        subset_variance(&rewards, &picked)
    );

    // 2. The full stack: three RL iterations of GRPO-PODS on `arith`,
    //    under the pipelined executor — iteration t+1's rollouts are
    //    generated on the rollout pool while iteration t updates.
    let cfg = CfgBuilder {
        name: "quickstart".into(),
        profile: "base".into(),
        task: "arith".into(),
        iterations: 3,
        prompts_per_iter: 1,
        eval_every: 3,
        eval_problems: 32,
        kind: "pods".into(),
        n: 32,
        m: Some(8),
        lr: 2e-4,
        schedule: "pipelined".into(),
        sft_steps: 60, // tiny warm-up so rollouts aren't pure noise
        sft_lr: 3e-3,
        out_dir: "results".into(),
        ..Default::default()
    }
    .build()?;
    let mut trainer = Trainer::new(&artifacts, cfg)?;
    trainer.run()?;

    let last = trainer.recorder.iters.last().unwrap();
    println!(
        "\nquickstart done: {} rollouts generated/iter, {} trained/iter, \
         final train reward {:.2}, sim step {:.1}s charged \
         (inference {:.1}s + update {:.1}s, {:.1}s hidden by overlap)",
        last.rollouts_generated,
        last.rollouts_trained,
        last.train_reward,
        last.sim_step_time,
        last.sim_inference_time,
        last.sim_update_time,
        last.sim_overlap_saved,
    );
    println!(
        "schedule {}: total sim {:.1}s, {:.1}s saved vs sync",
        last.schedule,
        trainer.clock.now(),
        trainer.clock.overlap_saved(),
    );
    println!("metrics: results/quickstart_train.csv, results/quickstart_eval.csv");
    Ok(())
}
