//! Selection-pipeline showcase (Fig. 5) — what each registered selector
//! keeps from the same synthetic prompt group, how pipelines compose, and
//! the full training comparison on setting (a).
//!
//! ```sh
//! cargo run --release --example downsample_rules -- [--quick] [--no-train]
//! ```

use pods::coordinator::group::PromptGroup;
use pods::coordinator::select::{Pipeline, SelectionContext};
use pods::exp::{fig5, Scale};

/// A synthetic group: a typical discrete RLVR reward multiset
/// (accuracy+format+tags) with spread-out generation lengths.
fn demo_group(rewards: &[f32], lens: &[i32]) -> PromptGroup {
    PromptGroup::synthetic(0, rewards, Some(lens))
}

fn show(group: &PromptGroup, spec: &str, m: usize) -> anyhow::Result<()> {
    let pipeline = Pipeline::parse_default(spec)?;
    let sel = pipeline.select(&SelectionContext::new(group, m, 0, 0))?;
    let vals: Vec<f32> = sel.kept.iter().map(|&i| group.rollouts[i].total_reward).collect();
    println!(
        "  {:<40} -> indices {:?} rewards {:?}\n  {:<40}    variance {:.3}, tokens kept {} / dropped {}{}",
        spec,
        sel.kept,
        vals,
        "",
        sel.diag.reward_variance,
        sel.diag.tokens_kept,
        sel.diag.tokens_dropped,
        if sel.kept.is_empty() { "  (group dropped: no learning signal)" } else { "" },
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let rewards = [3.0f32, 0.0, 2.0, 2.0, 0.25, 3.0, 1.0, 0.5, 2.0, 0.0, 3.0, 0.25];
    let lens = [22i32, 64, 30, 31, 120, 24, 45, 80, 28, 70, 26, 95];
    let group = demo_group(&rewards, &lens);
    let m = 4;
    println!("rewards: {rewards:?}");
    println!("lengths: {lens:?}, m = {m}");
    for spec in [
        "max_variance",
        "max_reward",
        "random",
        "percentile",
        "first",
        "drop_zero_variance | max_variance",
        "prune(max_tokens=64) | percentile",
        "prune(budget=128) | max_variance",
    ] {
        show(&group, spec, m)?;
    }

    // a zero-signal group (all rollouts correct): drop_zero_variance
    // removes it from the update entirely
    println!("\nall-equal rewards (no GRPO signal):");
    let flat = demo_group(&[1.0; 6], &[30, 30, 30, 30, 30, 30]);
    show(&flat, "max_variance", m)?;
    show(&flat, "drop_zero_variance | max_variance", m)?;

    if std::env::args().any(|a| a == "--no-train") {
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { Scale::Quick } else { Scale::Full };
    fig5::run(&pods::default_artifacts_dir(), scale, "results")?;
    Ok(())
}
