//! Down-sampling rule comparison (Fig. 5) plus a pure-algorithm showcase:
//! what each rule selects from the same reward multiset, and the full
//! training comparison on setting (a).
//!
//! ```sh
//! cargo run --release --example downsample_rules -- [--quick] [--no-train]
//! ```

use pods::coordinator::downsample::{subset_variance, Rule};
use pods::exp::{fig5, Scale};
use pods::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // A typical discrete RLVR reward multiset (accuracy+format+tags).
    let rewards = vec![3.0f32, 0.0, 2.0, 2.0, 0.25, 3.0, 1.0, 0.5, 2.0, 0.0, 3.0, 0.25];
    let m = 4;
    let mut rng = Rng::seed_from_u64(0);
    println!("rewards: {rewards:?}, m = {m}");
    for rule in [Rule::MaxVariance, Rule::MaxReward, Rule::Random, Rule::Percentile] {
        let sel = rule.select(&rewards, m, &mut rng);
        let vals: Vec<f32> = sel.iter().map(|&i| rewards[i]).collect();
        println!(
            "  {:<13} -> indices {:?} rewards {:?} (variance {:.3})",
            rule.name(),
            sel,
            vals,
            subset_variance(&rewards, &sel)
        );
    }

    if std::env::args().any(|a| a == "--no-train") {
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { Scale::Quick } else { Scale::Full };
    fig5::run(&pods::default_artifacts_dir(), scale, "results")?;
    Ok(())
}
