//! Distributed scenario (Table 1 settings e/f): 8 simulated workers,
//! full-parameter training, GRPO-GA (gradient accumulation over all n)
//! vs GRPO-PODS (down-sample to m before the update phase).
//!
//! Demonstrates the paper's central systems claim: at equal total rollouts,
//! PODS runs 4x fewer synchronized micro-steps, so the update phase — the
//! memory/communication-bound part — shrinks accordingly.
//!
//! ```sh
//! cargo run --release --example distributed_ga_vs_pods -- [--quick]
//! ```

use pods::exp::{fig3, Scale};
use pods::hwsim::HwModel;

fn main() -> anyhow::Result<()> {
    // First, the cost model's view of the trade (no training needed):
    let hw = HwModel { workers: 8, mem_capacity_rollouts: 4, ..Default::default() };
    let n_total = 128; // 64 rollouts x 2 prompts per iteration
    let m_total = 32;
    println!("hwsim, 8 workers, mem ceiling 4 rollouts/device:");
    println!(
        "  GRPO-GA  update on n={n_total}: {:>6.2}s ({} micro-steps)",
        hw.update_time(n_total, false),
        hw.forced_micro_steps(n_total)
    );
    println!(
        "  GRPO-PODS update on m={m_total}: {:>6.2}s ({} micro-steps)",
        hw.update_time(m_total, false),
        hw.forced_micro_steps(m_total)
    );

    // Then the real thing: setting (e) = GA vs PODS with training.
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { Scale::Quick } else { Scale::Full };
    fig3::run_setting(&pods::default_artifacts_dir(), "e", scale, "results")?;
    Ok(())
}
