//! Setting (a) end-to-end (Table 1): the paper's single-GPU LoRA scenario —
//! arith task, LoRA adapters over a frozen SFT base, GRPO-PODS(n=64, m=16)
//! vs the vanilla GRPO(16) baseline, accuracy-vs-wallclock comparison.
//!
//! This is the Fig. 3(a) driver exposed as a runnable example:
//!
//! ```sh
//! cargo run --release --example train_setting_a            # full
//! cargo run --release --example train_setting_a -- --quick # smoke
//! ```

use pods::exp::{fig3, Scale};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let scale = if quick { Scale::Quick } else { Scale::Full };
    fig3::run_setting(&pods::default_artifacts_dir(), "a", scale, "results")?;
    println!("CSV series: results/fig3_a_pods_*.csv vs results/fig3_a_grpo_*.csv");
    Ok(())
}
