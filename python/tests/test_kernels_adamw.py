"""Pallas fused AdamW kernel vs oracle + optimizer invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import ref
from compile.kernels.adamw import adamw_update


def _mk(rng, n):
    p = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    m = jnp.asarray(rng.normal(size=(n,)).astype(np.float32) * 0.1)
    v = jnp.asarray(np.abs(rng.normal(size=(n,))).astype(np.float32) * 0.01)
    return p, g, m, v


@given(
    nblk=st.integers(1, 8),
    blk=st.sampled_from([32, 128, 1024]),
    step=st.integers(0, 10_000),
    lr=st.sampled_from([1e-5, 1e-3, 0.1]),
    wd=st.sampled_from([0.0, 0.1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle(nblk, blk, step, lr, wd, seed):
    rng = np.random.default_rng(seed)
    n = nblk * blk
    p, g, m, v = _mk(rng, n)
    got = adamw_update(p, g, m, v, jnp.int32(step), lr=lr, wd=wd, blk=blk)
    want = ref.adamw_ref(p, g, m, v, step, lr, 0.9, 0.999, 1e-8, wd)
    for a, b in zip(got, want):
        # kernel computes bias correction in f32, oracle in python float64
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_zero_grad_zero_wd_is_near_identity_with_zero_moments():
    n = 256
    p = jnp.linspace(-1, 1, n, dtype=jnp.float32)
    z = jnp.zeros(n, dtype=jnp.float32)
    p2, m2, v2 = adamw_update(p, z, z, z, jnp.int32(0), lr=1e-3, wd=0.0, blk=64)
    np.testing.assert_allclose(p2, p, atol=1e-7)
    np.testing.assert_allclose(m2, z, atol=0)
    np.testing.assert_allclose(v2, z, atol=0)


def test_weight_decay_shrinks_params():
    n = 128
    p = jnp.ones(n, dtype=jnp.float32)
    z = jnp.zeros(n, dtype=jnp.float32)
    p2, _, _ = adamw_update(p, z, z, z, jnp.int32(0), lr=1e-2, wd=0.1, blk=64)
    np.testing.assert_allclose(p2, p * (1 - 1e-2 * 0.1), rtol=1e-6)


def test_step_size_bounded_by_lr():
    # bias-corrected Adam step magnitude is ~lr per coordinate for step 0
    rng = np.random.default_rng(0)
    n = 512
    p, g, m, v = _mk(rng, n)
    p2, _, _ = adamw_update(p, g, jnp.zeros(n), jnp.zeros(n), jnp.int32(0), lr=1e-3, wd=0.0, blk=128)
    step = np.abs(np.asarray(p2 - p))
    assert step.max() <= 1e-3 * 1.01


def test_block_size_invariance():
    rng = np.random.default_rng(1)
    n = 2048
    p, g, m, v = _mk(rng, n)
    a = adamw_update(p, g, m, v, jnp.int32(5), lr=1e-3, blk=256)
    b = adamw_update(p, g, m, v, jnp.int32(5), lr=1e-3, blk=2048)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=1e-7)
