"""Chunked early-exit decode: differential + invariance tests.

The Rust rollout engine rebuilds generation as ``prefill`` +
``decode_chunk`` calls with slot-based continuous refill. These tests pin
the contract that makes that sound:

* chunked decode == the monolithic rollout, bit for bit, for any chunk
  size (the per-step computation is shared, RNG is per-row counter-based);
* the same holds under the ``use_pallas=False`` jnp oracle;
* a slot driver that retires finished rows and admits queued rows in ANY
  order reproduces each row's token/logprob/mask stream exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import vocab as V
from compile.model import (
    ModelConfig,
    decode_chunk,
    init_params,
    merge_slots,
    prefill,
    prefill_shared,
    rollout,
    share_slots,
)

TINY = ModelConfig(
    d_model=32, layers=2, heads=2, d_ff=64, seq_len=24, prompt_len=8,
    rollout_batch=4, update_batch=2, pad_multiple=256, attn_block=8,
)


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jnp.uint32(0))


def _prompts(cfg, b, rng):
    toks = rng.integers(V.DIGIT0, V.DIGIT0 + 10, size=(b, cfg.prompt_len)).astype(np.int32)
    pad = rng.integers(0, cfg.prompt_len - 2, size=(b,)).astype(np.int32)
    for i in range(b):
        toks[i, : pad[i]] = V.PAD
    return jnp.asarray(toks), jnp.asarray(pad)


def _seeds(b, base):
    return jnp.asarray(np.arange(b) * 7919 + base, jnp.int32)


@pytest.mark.parametrize("chunk", [1, 4, 5, 16])
@pytest.mark.parametrize("use_pallas", [True, False], ids=["pallas", "oracle"])
def test_chunked_equals_monolithic(params, chunk, use_pallas):
    """Any chunk size replays the monolithic (chunk=G) streams bit-for-bit,
    both on the Pallas path and under the jnp oracle."""
    rng = np.random.default_rng(0)
    prompts, pad = _prompts(TINY, 4, rng)
    seeds = _seeds(4, 11)
    mono = rollout(TINY, params, prompts, pad, seeds, jnp.float32(1.0), use_pallas=use_pallas)
    chk = rollout(
        TINY, params, prompts, pad, seeds, jnp.float32(1.0), use_pallas=use_pallas, chunk=chunk
    )
    for name, a, b in zip(("tokens", "logprobs", "gen_mask", "gen_len"), mono, chk):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f"{name} diverged at chunk={chunk}")


def test_pallas_rollout_matches_oracle(params):
    """The full chunked rollout agrees with the use_pallas=False oracle
    (prefill is the only stage touching the Pallas attention kernel)."""
    rng = np.random.default_rng(1)
    prompts, pad = _prompts(TINY, 4, rng)
    seeds = _seeds(4, 3)
    a = rollout(TINY, params, prompts, pad, seeds, jnp.float32(1.0), use_pallas=True, chunk=4)
    b = rollout(TINY, params, prompts, pad, seeds, jnp.float32(1.0), use_pallas=False, chunk=4)
    # token streams must agree (sampling thresholds could flip only under
    # kernel drift far above the attention kernel's tolerance)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=1e-4, atol=1e-4)


def test_seed_stream_is_row_local(params):
    """A row's stream depends only on its own seed — not on its slot index
    or its neighbours (the old call-level key chain broke this)."""
    rng = np.random.default_rng(2)
    prompts, pad = _prompts(TINY, 4, rng)
    seeds = _seeds(4, 17)
    toks, lps, mask, _ = rollout(TINY, params, prompts, pad, seeds, jnp.float32(1.0))
    # permute the batch: each row must reproduce its stream in any slot
    perm = np.asarray([2, 0, 3, 1])
    toks_p, lps_p, mask_p, _ = rollout(
        TINY, params, prompts[perm], pad[perm], seeds[perm], jnp.float32(1.0)
    )
    np.testing.assert_array_equal(np.asarray(toks)[perm], np.asarray(toks_p))
    np.testing.assert_array_equal(np.asarray(lps)[perm], np.asarray(lps_p))
    np.testing.assert_array_equal(np.asarray(mask)[perm], np.asarray(mask_p))


def _reference_rows(params, prompts, pad, seeds, temperature):
    """Per-row reference streams from the monolithic rollout."""
    toks, lps, mask, glen = rollout(TINY, params, prompts, pad, seeds, temperature)
    return np.asarray(toks), np.asarray(lps), np.asarray(mask), np.asarray(glen)


def _drive_slots(params, prompts, pad, seeds, order, slots, chunk, temperature):
    """A Python mirror of the Rust slot driver: `slots` concurrent rows,
    refill in `order`, retire on done, early-exit when drained.

    Returns per-row (tokens[G], logprobs[G], mask[G]) arrays indexed by the
    original row index.
    """
    R = len(order)
    G, P = TINY.gen_len, TINY.prompt_len
    out_t = np.full((R, G), V.PAD, np.int32)
    out_l = np.zeros((R, G), np.float32)
    out_m = np.zeros((R, G), np.float32)

    queue = list(order)
    slot_row = [None] * slots

    def admit(free):
        """Prefill a batch carrying the newly admitted rows in their target
        slots (other slots hold a dummy prompt) and return its state."""
        rows = []
        for s in free:
            if queue:
                rows.append((s, queue.pop(0)))
        if not rows:
            return None
        batch_p = np.zeros((slots, P), np.int32)
        batch_pad = np.zeros((slots,), np.int32)
        for s, r in rows:
            batch_p[s] = np.asarray(prompts)[r]
            batch_pad[s] = np.asarray(pad)[r]
        ck, cv, lg = prefill(TINY, params, jnp.asarray(batch_p), jnp.asarray(batch_pad))
        return rows, np.asarray(ck), np.asarray(cv), np.asarray(lg), batch_pad

    first = admit(list(range(slots)))
    assert first is not None
    rows, ck, cv, lg, batch_pad = first
    step = np.zeros((slots,), np.int32)
    done = np.ones((slots,), np.int32)  # unfilled slots stay done
    slot_seed = np.zeros((slots,), np.int32)
    for s, r in rows:
        slot_row[s] = r
        done[s] = 0
        slot_seed[s] = int(np.asarray(seeds)[r])

    while True:
        tk, lp, mk, ck2, cv2, lg2, step2, done2 = decode_chunk(
            TINY, chunk, params,
            jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(lg),
            jnp.asarray(slot_seed), jnp.asarray(step), jnp.asarray(done),
            jnp.asarray(batch_pad), jnp.float32(temperature),
        )
        tk, lp, mk = np.asarray(tk), np.asarray(lp), np.asarray(mk)
        ck, cv, lg = np.array(ck2), np.array(cv2), np.array(lg2)
        prev_step = step.copy()
        step, done = np.array(step2), np.array(done2)
        # harvest masked outputs into each live row's stream
        for s in range(slots):
            r = slot_row[s]
            if r is None:
                continue
            for j in range(chunk):
                g = prev_step[s] + j
                if g < TINY.gen_len and mk[s, j] > 0:
                    out_t[r, g] = tk[s, j]
                    out_l[r, g] = lp[s, j]
                    out_m[r, g] = mk[s, j]
        # retire + refill
        free = []
        for s in range(slots):
            if slot_row[s] is not None and (done[s] != 0 or step[s] >= TINY.gen_len):
                slot_row[s] = None
                free.append(s)
        if free and queue:
            admitted = admit(free)
            if admitted is not None:
                rows, nck, ncv, nlg, npad = admitted
                # on-device merge, exactly as the Rust driver's admit_merge
                mask = np.zeros((slots,), np.int32)
                for s, _ in rows:
                    mask[s] = 1
                ck, cv, lg = (
                    np.array(x)
                    for x in merge_slots(
                        jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(lg),
                        jnp.asarray(nck), jnp.asarray(ncv), jnp.asarray(nlg),
                        jnp.asarray(mask),
                    )
                )
                for s, r in rows:
                    batch_pad[s] = npad[s]
                    step[s] = 0
                    done[s] = 0
                    slot_seed[s] = int(np.asarray(seeds)[r])
                    slot_row[s] = r
        if all(r is None for r in slot_row):
            break
    return out_t, out_l, out_m


@pytest.mark.parametrize("chunk", [1, 5, 16])
@pytest.mark.parametrize("perm_seed", [0, 1, 2])
def test_slot_refill_any_order_reproduces_streams(params, chunk, perm_seed):
    """Continuous batching with retirement + refill in arbitrary admission
    order reproduces every row's monolithic stream exactly — the property
    the Rust driver's correctness rests on."""
    R, slots = 7, 3
    rng = np.random.default_rng(40 + perm_seed)
    prompts, pad = _prompts(TINY, R, rng)
    seeds = _seeds(R, 100 + perm_seed)
    ref_t, ref_l, ref_m, _ = _reference_rows(params, prompts, pad, seeds, jnp.float32(1.2))
    order = list(rng.permutation(R))
    got_t, got_l, got_m = _drive_slots(
        params, prompts, pad, seeds, order, slots, chunk, 1.2
    )
    P = TINY.prompt_len
    np.testing.assert_array_equal(ref_t[:, P:], got_t)
    np.testing.assert_array_equal(ref_l, got_l)
    np.testing.assert_array_equal(ref_m, got_m)


def _drive_slots_shared(params, prompt_row, pad_scalar, seeds, order, slots, chunk, temperature):
    """The group-shared prompt-KV driver: ONE ``prefill_shared`` call for
    the whole group (every slot carries the group prompt), every later
    admission replicating the snapshot via ``share_slots`` — no further
    prompt passes. Mirrors the Rust driver's share_prompt_kv path."""
    R = len(order)
    G = TINY.gen_len
    out_t = np.full((R, G), V.PAD, np.int32)
    out_l = np.zeros((R, G), np.float32)
    out_m = np.zeros((R, G), np.float32)

    queue = list(order)
    batch_p = np.tile(np.asarray(prompt_row)[None, :], (slots, 1)).astype(np.int32)
    batch_pad = np.full((slots,), int(pad_scalar), np.int32)
    ck, cv, lg, sk, sv, sl = prefill_shared(
        TINY, params, jnp.asarray(batch_p), jnp.asarray(batch_pad)
    )
    ck, cv, lg = np.array(ck), np.array(cv), np.array(lg)

    slot_row = [None] * slots
    step = np.zeros((slots,), np.int32)
    done = np.ones((slots,), np.int32)  # unfilled slots stay done
    slot_seed = np.zeros((slots,), np.int32)
    for s in range(slots):
        if queue:
            r = queue.pop(0)
            slot_row[s] = r
            done[s] = 0
            slot_seed[s] = int(np.asarray(seeds)[r])

    while True:
        tk, lp, mk, ck2, cv2, lg2, step2, done2 = decode_chunk(
            TINY, chunk, params,
            jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(lg),
            jnp.asarray(slot_seed), jnp.asarray(step), jnp.asarray(done),
            jnp.asarray(batch_pad), jnp.float32(temperature),
        )
        tk, lp, mk = np.asarray(tk), np.asarray(lp), np.asarray(mk)
        ck, cv, lg = np.array(ck2), np.array(cv2), np.array(lg2)
        prev_step = step.copy()
        step, done = np.array(step2), np.array(done2)
        for s in range(slots):
            r = slot_row[s]
            if r is None:
                continue
            for j in range(chunk):
                g = prev_step[s] + j
                if g < TINY.gen_len and mk[s, j] > 0:
                    out_t[r, g] = tk[s, j]
                    out_l[r, g] = lp[s, j]
                    out_m[r, g] = mk[s, j]
        free = []
        for s in range(slots):
            if slot_row[s] is not None and (done[s] != 0 or step[s] >= TINY.gen_len):
                slot_row[s] = None
                free.append(s)
        if free and queue:
            mask = np.zeros((slots,), np.int32)
            admitted = []
            for s in free:
                if queue:
                    admitted.append((s, queue.pop(0)))
                    mask[s] = 1
            # sibling admission: the snapshot replicates on device and
            # passes through unchanged for the next refill
            ck, cv, lg, sk, sv, sl = (
                np.array(x)
                for x in share_slots(
                    jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(lg),
                    jnp.asarray(sk), jnp.asarray(sv), jnp.asarray(sl),
                    jnp.asarray(mask),
                )
            )
            for s, r in admitted:
                step[s] = 0
                done[s] = 0
                slot_seed[s] = int(np.asarray(seeds)[r])
                slot_row[s] = r
        if all(r is None for r in slot_row):
            break
    return out_t, out_l, out_m


@pytest.mark.parametrize("chunk", [1, 5, 16])
def test_shared_prefill_reproduces_streams(params, chunk):
    """Group-shared prompt KV is bit-identical to per-row prefill: one
    prompt pass + snapshot replication reproduces every sibling's
    monolithic stream exactly, in any admission order — the property the
    Rust driver's share_prompt_kv path rests on."""
    R, slots = 7, 3
    rng = np.random.default_rng(60)
    prompts, pad = _prompts(TINY, 1, rng)
    group_p = np.tile(np.asarray(prompts), (R, 1))
    group_pad = np.full((R,), int(np.asarray(pad)[0]), np.int32)
    seeds = _seeds(R, 200)
    ref_t, ref_l, ref_m, _ = _reference_rows(
        params, jnp.asarray(group_p), jnp.asarray(group_pad), seeds, jnp.float32(1.2)
    )
    order = list(rng.permutation(R))
    got_t, got_l, got_m = _drive_slots_shared(
        params, np.asarray(prompts)[0], np.asarray(pad)[0], seeds, order, slots, chunk, 1.2
    )
    P = TINY.prompt_len
    np.testing.assert_array_equal(ref_t[:, P:], got_t)
    np.testing.assert_array_equal(ref_l, got_l)
    np.testing.assert_array_equal(ref_m, got_m)


def test_decode_chunk_overshoot_is_inert(params):
    """Chunks that run past the generation budget G write nothing: done
    rows emit PAD/0/0 and the caches stay untouched."""
    rng = np.random.default_rng(5)
    prompts, pad = _prompts(TINY, 4, rng)
    seeds = _seeds(4, 9)
    ck, cv, lg = prefill(TINY, params, prompts, pad)
    G = TINY.gen_len
    step = jnp.full((4,), G, jnp.int32)
    done = jnp.zeros((4,), jnp.int32)  # driver would have set it; program must self-guard
    tk, lp, mk, ck2, cv2, _, step2, done2 = decode_chunk(
        TINY, 3, params, ck, cv, lg, seeds, step, done, pad, jnp.float32(1.0)
    )
    assert (np.asarray(tk) == V.PAD).all()
    assert (np.asarray(lp) == 0).all()
    assert (np.asarray(mk) == 0).all()
    assert (np.asarray(done2) == 1).all()
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(ck2))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(cv2))
