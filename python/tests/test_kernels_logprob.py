"""Pallas logprob kernel vs pure-jnp oracle: hypothesis shape/value sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import ref
from compile.kernels.logprob import logprob


def _mk(rng, rows, v, scale=1.0):
    logits = jnp.asarray(rng.normal(size=(rows, v)).astype(np.float32) * scale)
    labels = jnp.asarray(rng.integers(0, v, size=(rows,)).astype(np.int32))
    return logits, labels


@given(
    rows=st.integers(1, 97),
    v=st.integers(2, 300),
    blk_r=st.sampled_from([8, 16, 64]),
    v_tile=st.sampled_from([16, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle(rows, v, blk_r, v_tile, seed):
    rng = np.random.default_rng(seed)
    logits, labels = _mk(rng, rows, v)
    got = logprob(logits, labels, blk_r, v_tile)
    want = ref.logprob_ref(logits, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    rows=st.integers(1, 40),
    v=st.integers(2, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_grad_matches_oracle(rows, v, seed):
    rng = np.random.default_rng(seed)
    logits, labels = _mk(rng, rows, v)
    cot = jnp.asarray(rng.normal(size=(rows,)).astype(np.float32))
    g = jax.grad(lambda x: jnp.vdot(logprob(x, labels, 16, 32), cot))(logits)
    g_ref = jax.grad(lambda x: jnp.vdot(ref.logprob_ref(x, labels), cot))(logits)
    np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-5)


def test_extreme_logits_stable():
    # online logsumexp must not overflow for large-magnitude logits
    logits = jnp.asarray([[1000.0, -1000.0, 999.0, 0.0]], dtype=jnp.float32)
    labels = jnp.asarray([2], dtype=jnp.int32)
    got = logprob(logits, labels, 8, 2)
    want = ref.logprob_ref(logits, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


def test_probability_normalisation():
    # exp(logprob over all labels) must sum to 1 per row
    rng = np.random.default_rng(3)
    v = 17
    logits = jnp.asarray(rng.normal(size=(1, v)).astype(np.float32))
    total = 0.0
    for lbl in range(v):
        total += float(jnp.exp(logprob(logits, jnp.asarray([lbl], dtype=jnp.int32), 8, 8))[0])
    assert abs(total - 1.0) < 1e-4


def test_vocab_tile_invariance():
    # result must not depend on the tiling
    rng = np.random.default_rng(11)
    logits, labels = _mk(rng, 13, 130)
    a = logprob(logits, labels, 8, 16)
    b = logprob(logits, labels, 64, 512)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("rows,v", [(64, 48), (768, 48), (8, 48)])
def test_production_shapes(rows, v):
    # the shapes the grad/sft artifacts actually use
    rng = np.random.default_rng(rows)
    logits, labels = _mk(rng, rows, v, scale=3.0)
    got = logprob(logits, labels)
    want = ref.logprob_ref(logits, labels)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
