"""L2 model invariants: shapes, causality, rollout consistency, grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import vocab as V
from compile.model import (
    ModelConfig,
    apply_update,
    forward,
    gen_logprobs,
    grpo_grad,
    init_lora,
    init_params,
    lora_count,
    lora_specs,
    param_count,
    param_specs,
    rollout,
    sft_step,
    unpack,
)

TINY = ModelConfig(
    d_model=32, layers=2, heads=2, d_ff=64, seq_len=24, prompt_len=8,
    rollout_batch=4, update_batch=2, pad_multiple=256, attn_block=8,
)


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jnp.uint32(0))


def _prompts(cfg, b, rng):
    toks = rng.integers(V.DIGIT0, V.DIGIT0 + 10, size=(b, cfg.prompt_len)).astype(np.int32)
    pad = rng.integers(0, cfg.prompt_len - 2, size=(b,)).astype(np.int32)
    for i in range(b):
        toks[i, : pad[i]] = V.PAD
    return jnp.asarray(toks), jnp.asarray(pad)


def _seeds(b, base):
    """Distinct per-row RNG seeds (the rollout signature is seeds i32[B])."""
    return jnp.asarray(np.arange(b) + base * 1000, jnp.int32)


def test_param_count_padding():
    n = param_count(TINY)
    assert n % TINY.pad_multiple == 0
    used = sum(int(np.prod(s)) for _, s in param_specs(TINY))
    assert 0 <= n - used < TINY.pad_multiple


def test_init_deterministic(params):
    p2 = init_params(TINY, jnp.uint32(0))
    np.testing.assert_array_equal(params, p2)
    p3 = init_params(TINY, jnp.uint32(1))
    assert float(jnp.abs(params - p3).max()) > 0


def test_forward_shapes_and_finite(params):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, size=(3, TINY.seq_len)).astype(np.int32))
    pad = jnp.asarray([0, 2, 5], dtype=jnp.int32)
    pt = unpack(param_specs(TINY), params)
    logits = forward(TINY, pt, toks, pad)
    assert logits.shape == (3, TINY.seq_len, TINY.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_causality(params):
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, size=(1, TINY.seq_len)).astype(np.int32))
    pad = jnp.zeros((1,), jnp.int32)
    pt = unpack(param_specs(TINY), params)
    a = forward(TINY, pt, toks, pad)
    toks2 = toks.at[0, -1].set((int(toks[0, -1]) + 1) % TINY.vocab)
    b = forward(TINY, pt, toks2, pad)
    np.testing.assert_allclose(a[0, :-1], b[0, :-1], atol=1e-5)


def test_forward_pallas_matches_ref(params):
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, TINY.vocab, size=(2, TINY.seq_len)).astype(np.int32))
    pad = jnp.asarray([0, 3], dtype=jnp.int32)
    pt = unpack(param_specs(TINY), params)
    a = forward(TINY, pt, toks, pad, use_pallas=True)
    b = forward(TINY, pt, toks, pad, use_pallas=False)
    # compare on valid rows only
    m = (jnp.arange(TINY.seq_len)[None, :] >= pad[:, None])[..., None]
    np.testing.assert_allclose(jnp.where(m, a, 0), jnp.where(m, b, 0), rtol=1e-4, atol=1e-4)


def test_rollout_shapes_and_determinism(params):
    rng = np.random.default_rng(3)
    prompts, pad = _prompts(TINY, 4, rng)
    toks, lps, mask, glen = rollout(TINY, params, prompts, pad, _seeds(4, 7), jnp.float32(1.0))
    assert toks.shape == (4, TINY.seq_len)
    assert lps.shape == (4, TINY.gen_len)
    assert mask.shape == (4, TINY.gen_len)
    np.testing.assert_array_equal(np.asarray(toks[:, : TINY.prompt_len]), np.asarray(prompts))
    toks2, lps2, _, _ = rollout(TINY, params, prompts, pad, _seeds(4, 7), jnp.float32(1.0))
    np.testing.assert_array_equal(toks, toks2)
    toks3, _, _, _ = rollout(TINY, params, prompts, pad, _seeds(4, 8), jnp.float32(1.0))
    assert np.any(np.asarray(toks) != np.asarray(toks3))


def test_rollout_mask_eos_contract(params):
    rng = np.random.default_rng(4)
    prompts, pad = _prompts(TINY, 6, rng)
    toks, lps, mask, glen = rollout(TINY, params, prompts, pad, _seeds(6, 1), jnp.float32(1.5))
    toks, lps, mask, glen = map(np.asarray, (toks, lps, mask, glen))
    gen = toks[:, TINY.prompt_len :]
    for b in range(6):
        n = int(glen[b])
        assert mask[b, :n].all() and not mask[b, n:].any()
        # after EOS: PAD and zero logprob
        if n < TINY.gen_len:
            assert (gen[b, n:] == V.PAD).all()
            assert (lps[b, n:] == 0).all()
        eos_pos = np.where(gen[b] == V.EOS)[0]
        if len(eos_pos):
            assert n == eos_pos[0] + 1


def test_rollout_greedy_matches_forward_argmax(params):
    # temp<=0: each generated token must equal argmax of teacher-forced logits
    rng = np.random.default_rng(5)
    prompts, pad = _prompts(TINY, 3, rng)
    toks, _, mask, _ = rollout(TINY, params, prompts, pad, _seeds(3, 0), jnp.float32(0.0))
    pt = unpack(param_specs(TINY), params)
    logits = forward(TINY, pt, toks, pad)
    P = TINY.prompt_len
    pred = np.asarray(jnp.argmax(logits[:, P - 1 : TINY.seq_len - 1], axis=-1))
    gen = np.asarray(toks[:, P:])
    m = np.asarray(mask).astype(bool)
    np.testing.assert_array_equal(gen[m], pred[m])


def test_rollout_logprobs_match_teacher_forced(params):
    # behaviour logprobs recorded during decode == teacher-forced gen_logprobs
    rng = np.random.default_rng(6)
    prompts, pad = _prompts(TINY, 4, rng)
    toks, lps, mask, _ = rollout(TINY, params, prompts, pad, _seeds(4, 2), jnp.float32(1.0))
    lp_tf = gen_logprobs(TINY, params, toks, pad)
    m = np.asarray(mask).astype(bool)
    np.testing.assert_allclose(np.asarray(lps)[m], np.asarray(lp_tf)[m], rtol=1e-3, atol=1e-3)


def test_grpo_grad_zero_at_identity_with_zero_adv(params):
    rng = np.random.default_rng(7)
    prompts, pad = _prompts(TINY, 2, rng)
    toks, lps, mask, _ = rollout(TINY, params, prompts, pad, _seeds(2, 3), jnp.float32(1.0))
    adv = jnp.zeros((2,), jnp.float32)
    zeros = jnp.zeros_like(lps)
    grads, loss, cf, kl = grpo_grad(TINY, params, toks, pad, mask, lps, adv, zeros, jnp.float32(0.0))
    assert float(jnp.abs(grads).max()) < 1e-6
    assert abs(float(loss)) < 1e-6


def test_grpo_grad_direction(params):
    # positive advantage should increase logprob of that rollout after a step
    rng = np.random.default_rng(8)
    prompts, pad = _prompts(TINY, 2, rng)
    toks, lps, mask, _ = rollout(TINY, params, prompts, pad, _seeds(2, 4), jnp.float32(1.0))
    adv = jnp.asarray([1.0, -1.0], jnp.float32)
    zeros = jnp.zeros_like(lps)
    grads, loss, _, _ = grpo_grad(TINY, params, toks, pad, mask, lps, adv, zeros, jnp.float32(0.0))
    m = jnp.zeros_like(grads)
    v = jnp.zeros_like(grads)
    p2, _, _ = apply_update(TINY, params, m, v, jnp.int32(0), grads, jnp.float32(1e-3))
    lp2 = gen_logprobs(TINY, p2, toks, pad)
    msk = np.asarray(mask)
    lp_old = np.asarray(lps)
    lp_new = np.asarray(lp2)
    d0 = ((lp_new - lp_old) * msk)[0].sum() / max(msk[0].sum(), 1)
    d1 = ((lp_new - lp_old) * msk)[1].sum() / max(msk[1].sum(), 1)
    assert d0 > 0 > d1


def test_sft_step_reduces_loss(params):
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(V.DIGIT0, V.DIGIT0 + 10, size=(4, TINY.seq_len)).astype(np.int32))
    pad = jnp.zeros((4,), jnp.int32)
    mask = jnp.ones((4, TINY.seq_len), jnp.float32)
    p, m, v = params, jnp.zeros_like(params), jnp.zeros_like(params)
    losses = []
    for i in range(8):
        p, m, v, loss = sft_step(TINY, p, m, v, jnp.int32(i), toks, pad, mask, jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_lora_mode(params):
    cfg = ModelConfig(
        d_model=32, layers=2, heads=2, d_ff=64, seq_len=24, prompt_len=8,
        rollout_batch=4, update_batch=2, pad_multiple=256, attn_block=8,
        lora_rank=4, lora_alpha=4.0,
    )
    lora = init_lora(cfg, jnp.uint32(0))
    assert lora.shape[0] == lora_count(cfg)
    rng = np.random.default_rng(10)
    prompts, pad = _prompts(cfg, 2, rng)
    # B=0 at init => adapters are identity: rollout must match base model
    t1, l1, m1, _ = rollout(cfg, params, prompts, pad, _seeds(2, 5), jnp.float32(1.0), lora_flat=lora)
    t2, l2, m2, _ = rollout(cfg, params, prompts, pad, _seeds(2, 5), jnp.float32(1.0))
    np.testing.assert_array_equal(t1, t2)
    # grads flow to the lora vector and have its shape
    adv = jnp.asarray([1.0, -1.0], jnp.float32)
    zeros = jnp.zeros_like(l1)
    grads, loss, _, _ = grpo_grad(cfg, lora, t1, pad, m1, l1, adv, zeros, jnp.float32(0.0), base=params)
    assert grads.shape == lora.shape
    assert float(jnp.abs(grads).max()) > 0
