"""Pallas flash-attention kernel vs oracle.

Comparisons are restricted to *valid* query rows (pos >= pad_len): fully
masked padding rows are don't-care by contract (both implementations emit
finite garbage there, which downstream losses mask out).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention


def _mk(rng, b, h, t, dh):
    q = jnp.asarray(rng.normal(size=(b, h, t, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, t, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, t, dh)).astype(np.float32))
    pad = jnp.asarray(rng.integers(0, t, size=(b,)).astype(np.int32))
    return q, k, v, pad


def _valid(pad, t):
    return (jnp.arange(t)[None, :] >= pad[:, None])[:, None, :, None]


@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    t=st.integers(2, 70),
    dh=st.sampled_from([8, 16, 32]),
    blk=st.sampled_from([(16, 16), (32, 32), (16, 32)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle(b, h, t, dh, blk, seed):
    rng = np.random.default_rng(seed)
    q, k, v, pad = _mk(rng, b, h, t, dh)
    got = attention(q, k, v, pad, *blk)
    want = ref.attention_ref(q, k, v, pad)
    m = _valid(pad, t)
    np.testing.assert_allclose(
        jnp.where(m, got, 0.0), jnp.where(m, want, 0.0), rtol=1e-4, atol=1e-4
    )


@given(seed=st.integers(0, 2**31 - 1))
def test_grad_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    b, h, t, dh = 2, 2, 24, 8
    q, k, v, pad = _mk(rng, b, h, t, dh)
    m = _valid(pad, t)

    def loss_k(q_, k_, v_):
        return jnp.sum(jnp.where(m, attention(q_, k_, v_, pad, 16, 16), 0.0) ** 2)

    def loss_r(q_, k_, v_):
        return jnp.sum(jnp.where(m, ref.attention_ref(q_, k_, v_, pad), 0.0) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=1e-3, atol=1e-4)


def test_causality():
    # perturbing a future token must not change earlier outputs
    rng = np.random.default_rng(7)
    b, h, t, dh = 1, 2, 32, 16
    q, k, v, pad = _mk(rng, b, h, t, dh)
    pad = jnp.zeros((b,), dtype=jnp.int32)
    o1 = attention(q, k, v, pad, 16, 16)
    k2 = k.at[:, :, t - 1].add(10.0)
    v2 = v.at[:, :, t - 1].add(10.0)
    o2 = attention(q, k2, v2, pad, 16, 16)
    np.testing.assert_allclose(o1[:, :, : t - 1], o2[:, :, : t - 1], atol=1e-6)
    assert float(jnp.abs(o1[:, :, t - 1] - o2[:, :, t - 1]).max()) > 1e-3


def test_padding_isolation():
    # perturbing padding keys must not change valid outputs
    rng = np.random.default_rng(9)
    b, h, t, dh = 2, 2, 32, 16
    q, k, v, _ = _mk(rng, b, h, t, dh)
    pad = jnp.asarray([4, 9], dtype=jnp.int32)
    o1 = attention(q, k, v, pad, 16, 16)
    k2 = k.at[0, :, :4].add(5.0).at[1, :, :9].add(5.0)
    v2 = v.at[0, :, :4].add(5.0).at[1, :, :9].add(5.0)
    o2 = attention(q, k2, v2, pad, 16, 16)
    m = _valid(pad, t)
    np.testing.assert_allclose(
        jnp.where(m, o1, 0.0), jnp.where(m, o2, 0.0), atol=1e-5
    )


def test_single_visible_key_returns_value():
    # query at position pad_len sees exactly one key: output == its value row
    rng = np.random.default_rng(5)
    b, h, t, dh = 1, 1, 16, 8
    q, k, v, _ = _mk(rng, b, h, t, dh)
    pad = jnp.asarray([6], dtype=jnp.int32)
    o = attention(q, k, v, pad, 16, 16)
    np.testing.assert_allclose(o[0, 0, 6], v[0, 0, 6], rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    rng = np.random.default_rng(13)
    q, k, v, pad = _mk(rng, 2, 2, 48, 16)
    m = _valid(pad, 48)
    a = jnp.where(m, attention(q, k, v, pad, 16, 16), 0.0)
    b_ = jnp.where(m, attention(q, k, v, pad, 16, 48), 0.0)
    c = jnp.where(m, attention(q, k, v, pad, 48, 16), 0.0)
    np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)
