import os
import sys

# Make `compile.*` importable when pytest is invoked from the repo root or
# from python/.
_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

from hypothesis import settings

# Interpret-mode Pallas kernels trace slowly; keep example counts modest but
# meaningful, and disable the deadline (tracing dominates, not the property).
settings.register_profile("kernels", max_examples=20, deadline=None)
settings.load_profile("kernels")
