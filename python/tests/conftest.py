import os
import sys

# Make `compile.*` importable when pytest is invoked from the repo root or
# from python/.
_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

# Interpret-mode Pallas kernels trace slowly; keep example counts modest but
# meaningful, and disable the deadline (tracing dominates, not the property).
# hypothesis is optional: without it the property-based kernel tests skip at
# import time but the rest of the suite still collects and runs.
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("kernels", max_examples=20, deadline=None)
    settings.load_profile("kernels")

collect_ignore_glob = []
if settings is None:
    # the kernel property suites import hypothesis at module scope
    collect_ignore_glob += ["test_kernels_*.py"]
