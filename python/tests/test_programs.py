"""AOT program-builder tests: signatures, shapes, and HLO lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import vocab as V
from compile.aot import PROFILES, build_programs, decode_chunk_sizes, to_hlo_text
from compile.model import ModelConfig, lora_count, param_count


TINY = ModelConfig(
    d_model=16, layers=1, heads=2, d_ff=32, seq_len=12, prompt_len=4,
    rollout_batch=2, update_batch=2, pad_multiple=64, attn_block=4,
)
TINY_LORA = ModelConfig(
    d_model=16, layers=1, heads=2, d_ff=32, seq_len=12, prompt_len=4,
    rollout_batch=2, update_batch=2, pad_multiple=64, attn_block=4,
    lora_rank=2, lora_alpha=2.0,
)


def _run(progs, name):
    fn, args, _ = progs[name]
    vals = []
    rng = np.random.default_rng(0)
    for argname, spec in args:
        if spec.dtype == jnp.int32 and spec.shape:
            if argname == "tokens" or argname == "prompts":
                vals.append(jnp.asarray(rng.integers(0, TINY.vocab, spec.shape), jnp.int32))
            else:
                vals.append(jnp.zeros(spec.shape, jnp.int32))
        elif spec.dtype == jnp.int32:
            vals.append(jnp.int32(0))
        elif spec.dtype == jnp.uint32:
            vals.append(jnp.uint32(1))
        elif spec.shape == ():
            vals.append(jnp.float32(0.5))
        else:
            vals.append(jnp.asarray(rng.normal(size=spec.shape) * 0.02, jnp.float32))
    return fn(*vals)


@pytest.mark.parametrize("cfg", [TINY, TINY_LORA], ids=["full", "lora"])
def test_program_outputs_match_declared_shapes(cfg):
    progs = build_programs(cfg)
    expected = {
        "init", "rollout", "prefill", "prefill_shared", "admit_merge",
        "admit_share", "grad", "update", "score",
    }
    expected |= {f"decode_chunk{c}" for c in decode_chunk_sizes(cfg)}
    if cfg.lora_rank == 0:
        expected.add("sft")
    assert set(progs) == expected
    for name, (fn, args, out_names) in progs.items():
        outs = jax.eval_shape(fn, *[s for _, s in args])
        outs = outs if isinstance(outs, (tuple, list)) else (outs,)
        assert len(outs) == len(out_names), name
    # trainable width consistency
    nt = lora_count(cfg) if cfg.lora_rank else param_count(cfg)
    upd_args = dict((n, s) for n, s in progs["update"][1])
    assert upd_args["trainable"].shape == (nt,)
    assert upd_args["grads"].shape == (nt,)
    grad_args = dict((n, s) for n, s in progs["grad"][1])
    assert grad_args["trainable"].shape == (nt,)


def test_grad_program_executes_and_shapes(capsys):
    progs = build_programs(TINY)
    grads, loss, clip_frac, kl = _run(progs, "grad")
    assert grads.shape == (param_count(TINY),)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(clip_frac) <= 1.0
    assert np.isfinite(float(kl))


def test_rollout_program_executes(capsys):
    progs = build_programs(TINY)
    tokens, logprobs, gen_mask, gen_len = _run(progs, "rollout")
    assert tokens.shape == (TINY.rollout_batch, TINY.seq_len)
    assert logprobs.shape == (TINY.rollout_batch, TINY.gen_len)
    assert np.all(np.asarray(gen_len) >= 0)


def test_decode_path_programs_execute(capsys):
    progs = build_programs(TINY)
    L, B, H, T, dh = 1, 2, 2, 12, 8
    ck, cv, lg = _run(progs, "prefill")
    assert ck.shape == (L, B, H, T, dh)
    assert cv.shape == (L, B, H, T, dh)
    assert lg.shape == (B, TINY.vocab)
    assert decode_chunk_sizes(TINY) == [1, 4, 8]  # G=8 for this config
    toks, lps, mask, ck2, cv2, lg2, step, done = _run(progs, "decode_chunk4")
    assert toks.shape == (B, 4)
    assert ck2.shape == (L, B, H, T, dh)
    assert step.shape == (B,) and done.shape == (B,)
    assert np.all(np.asarray(step) == 4)
    mk, mv, ml = _run(progs, "admit_merge")
    assert mk.shape == (L, B, H, T, dh) and mv.shape == mk.shape
    assert ml.shape == (B, TINY.vocab)
    # the shared-prefill path duplicates the prompt state into a snapshot
    sck, scv, slg, snk, snv, snl = _run(progs, "prefill_shared")
    assert sck.shape == (L, B, H, T, dh) and snk.shape == sck.shape
    assert slg.shape == (B, TINY.vocab) and snl.shape == slg.shape
    np.testing.assert_array_equal(np.asarray(sck), np.asarray(snk))
    np.testing.assert_array_equal(np.asarray(scv), np.asarray(snv))
    np.testing.assert_array_equal(np.asarray(slg), np.asarray(snl))
    # admit_share merges like admit_merge and passes the snapshot through
    ak, av, al, rk, rv, rl = _run(progs, "admit_share")
    assert ak.shape == (L, B, H, T, dh) and rk.shape == ak.shape
    assert al.shape == (B, TINY.vocab) and rl.shape == al.shape


def test_lowering_produces_hlo_text():
    progs = build_programs(TINY)
    fn, args, _ = progs["update"]
    lowered = jax.jit(fn).lower(*[s for _, s in args])
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert len(text) > 1000


def test_profiles_are_consistent():
    for name, cfg in PROFILES.items():
        assert cfg.seq_len == cfg.prompt_len + cfg.gen_len
        assert cfg.d_model % cfg.heads == 0
        assert cfg.vocab == V.VOCAB_SIZE
        assert param_count(cfg) % cfg.pad_multiple == 0, name
    # the big profile is the ~100M composition-proof config
    big = PROFILES["big"]
    assert 80e6 < param_count(big) < 120e6
