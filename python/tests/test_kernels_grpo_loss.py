"""Pallas GRPO surrogate kernel vs oracle + analytic properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import ref
from compile.kernels.grpo_loss import grpo_objective


def _mk(rng, b, g):
    nlp = jnp.asarray(rng.normal(size=(b, g)).astype(np.float32) * 0.3 - 1.0)
    olp = jnp.asarray(rng.normal(size=(b, g)).astype(np.float32) * 0.3 - 1.0)
    adv = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
    mask = jnp.asarray((rng.random((b, g)) < 0.8).astype(np.float32))
    return nlp, olp, adv, mask


@given(
    b=st.integers(1, 33),
    g=st.integers(1, 80),
    eps=st.sampled_from([0.1, 0.2, 0.3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_oracle(b, g, eps, seed):
    rng = np.random.default_rng(seed)
    nlp, olp, adv, mask = _mk(rng, b, g)
    obj, cf = grpo_objective(nlp, olp, adv, mask, eps)
    obj_r, cf_r = ref.grpo_loss_ref(nlp, olp, adv, mask, eps)
    np.testing.assert_allclose(obj, obj_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(cf, cf_r, rtol=1e-5, atol=1e-6)


@given(b=st.integers(1, 16), g=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_grad_matches_oracle(b, g, seed):
    rng = np.random.default_rng(seed)
    nlp, olp, adv, mask = _mk(rng, b, g)
    cot = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
    grad_k = jax.grad(lambda x: jnp.vdot(grpo_objective(x, olp, adv, mask, 0.2)[0], cot))(nlp)
    grad_r = jax.grad(lambda x: jnp.vdot(ref.grpo_loss_ref(x, olp, adv, mask, 0.2)[0], cot))(nlp)
    np.testing.assert_allclose(grad_k, grad_r, rtol=1e-4, atol=1e-5)


def test_identity_policy_objective_is_advantage():
    # new == old -> ratio 1 -> obj_i = a_i (mask-mean of a_i over tokens)
    rng = np.random.default_rng(0)
    nlp, _, adv, mask = _mk(rng, 8, 32)
    mask = jnp.ones_like(mask)
    obj, cf = grpo_objective(nlp, nlp, adv, mask, 0.2)
    np.testing.assert_allclose(obj, adv, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(cf, np.zeros(8), atol=1e-7)


def test_fully_masked_rollout_contributes_zero():
    rng = np.random.default_rng(1)
    nlp, olp, adv, mask = _mk(rng, 4, 16)
    mask = mask.at[2].set(0.0)
    obj, cf = grpo_objective(nlp, olp, adv, mask, 0.2)
    assert float(obj[2]) == 0.0 and float(cf[2]) == 0.0


def test_clip_asymmetry_slow_to_adopt():
    # positive advantage + ratio far above 1+eps -> objective capped (clipped)
    # negative advantage + ratio far above 1+eps -> NOT capped (min picks r*a)
    olp = jnp.zeros((2, 1), dtype=jnp.float32)
    nlp = jnp.full((2, 1), 1.0, dtype=jnp.float32)  # ratio = e ~ 2.72
    adv = jnp.asarray([1.0, -1.0], dtype=jnp.float32)
    mask = jnp.ones((2, 1), dtype=jnp.float32)
    obj, cf = grpo_objective(nlp, olp, adv, mask, 0.2)
    np.testing.assert_allclose(obj[0], 1.2, rtol=1e-5)  # clip(e) * 1 = 1.2
    np.testing.assert_allclose(obj[1], -float(np.e), rtol=1e-5)
    assert float(cf[0]) == 1.0 and float(cf[1]) == 0.0


def test_gradient_zero_when_clipped_saturated():
    # positive adv, ratio above 1+eps: clipped branch active and saturated ->
    # zero gradient ("slow to adopt")
    olp = jnp.zeros((1, 1), dtype=jnp.float32)
    nlp = jnp.full((1, 1), 1.0, dtype=jnp.float32)
    adv = jnp.ones((1,), dtype=jnp.float32)
    mask = jnp.ones((1, 1), dtype=jnp.float32)
    g = jax.grad(lambda x: grpo_objective(x, olp, adv, mask, 0.2)[0].sum())(nlp)
    assert float(jnp.abs(g).max()) == 0.0
    # negative adv, same ratio: unclipped branch active -> gradient flows
    g2 = jax.grad(lambda x: grpo_objective(x, olp, -adv, mask, 0.2)[0].sum())(nlp)
    assert float(jnp.abs(g2).max()) > 0.1
