"""L2: the policy model and every training/inference computation, in JAX.

A decoder-only pre-LN transformer LM with learned positional embeddings and
a weight-tied LM head.  All parameters live in **one flat f32 vector**
(padded to a block multiple for the fused AdamW kernel) so the Rust runtime
manages exactly three device buffers: params, adam-m, adam-v.

Sequence layout: prompts are **left-padded** to ``prompt_len`` (``pad_len[b]``
counts leading PAD tokens), so generation uniformly occupies positions
``P .. T-1`` and every per-token tensor in the RL objective is ``[B, G]``.
Positional embeddings are indexed by ``position - pad_len`` so padding does
not shift the learned positions.

Compute hot spots call the L1 Pallas kernels (attention, logprob,
grpo_objective, adamw); ``use_pallas=False`` switches to the jnp oracles for
differential testing.

The functions here are pure; ``programs.py`` binds them into the AOT program
signatures that ``aot.py`` lowers to HLO text.
"""

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import vocab as V
from .kernels import ref as kref
from .kernels.adamw import adamw_update
from .kernels.attention import attention as attention_pallas
from .kernels.grpo_loss import grpo_objective
from .kernels.logprob import logprob as logprob_pallas

NEG = kref.NEG


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model/program dimensions; one profile == one artifact set."""

    vocab: int = V.VOCAB_SIZE
    d_model: int = 128
    layers: int = 4
    heads: int = 4
    d_ff: int = 512
    seq_len: int = 96  # T = prompt_len + gen_len
    prompt_len: int = 32  # P
    rollout_batch: int = 16  # B_r: rollouts per inference-program call
    update_batch: int = 8  # B_u: rollouts per grad-program micro-batch
    lora_rank: int = 0  # 0 = full-parameter training
    lora_alpha: float = 0.0  # scale = alpha / rank (paper: alpha == rank)
    clip_eps: float = 0.2  # GRPO ratio clip
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    weight_decay: float = 0.1  # Table 2
    pad_multiple: int = 4096  # flat-vector padding for the AdamW kernel
    attn_block: int = 32  # Pallas attention blk_q == blk_k

    @property
    def gen_len(self) -> int:
        return self.seq_len - self.prompt_len

    @property
    def d_head(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) spec of the full parameter set."""
    d, dff = cfg.d_model, cfg.d_ff
    specs = [("tok_emb", (cfg.vocab, d)), ("pos_emb", (cfg.seq_len, d))]
    for l in range(cfg.layers):
        specs += [
            (f"l{l}.ln1_s", (d,)),
            (f"l{l}.ln1_b", (d,)),
            (f"l{l}.wq", (d, d)),
            (f"l{l}.wk", (d, d)),
            (f"l{l}.wv", (d, d)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.ln2_s", (d,)),
            (f"l{l}.ln2_b", (d,)),
            (f"l{l}.w1", (d, dff)),
            (f"l{l}.b1", (dff,)),
            (f"l{l}.w2", (dff, d)),
            (f"l{l}.b2", (d,)),
        ]
    specs += [("lnf_s", (d,)), ("lnf_b", (d,))]
    return specs


def lora_specs(cfg: ModelConfig):
    """Ordered (name, shape) spec of the LoRA adapter set (q and v proj)."""
    r, d = cfg.lora_rank, cfg.d_model
    specs = []
    for l in range(cfg.layers):
        specs += [
            (f"l{l}.lora_qA", (r, d)),
            (f"l{l}.lora_qB", (d, r)),
            (f"l{l}.lora_vA", (r, d)),
            (f"l{l}.lora_vB", (d, r)),
        ]
    return specs


def _size(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def flat_size(specs, pad_multiple):
    n = sum(_size(s) for _, s in specs)
    return n + (-n) % pad_multiple


def param_count(cfg: ModelConfig) -> int:
    return flat_size(param_specs(cfg), cfg.pad_multiple)


def lora_count(cfg: ModelConfig) -> int:
    return flat_size(lora_specs(cfg), cfg.pad_multiple)


def unpack(specs, flat):
    """Flat f32[N] -> dict name -> array (static slices, free under XLA)."""
    out = {}
    off = 0
    for name, shape in specs:
        sz = _size(shape)
        out[name] = flat[off : off + sz].reshape(shape)
        off += sz
    return out


def pack(specs, tree, pad_multiple):
    parts = [tree[name].reshape(-1) for name, _ in specs]
    flat = jnp.concatenate(parts)
    pad = (-flat.shape[0]) % pad_multiple
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat


def spec_meta(specs, pad_multiple):
    """JSON-ready offset table for meta.json (Rust checkpoint tooling)."""
    out = []
    off = 0
    for name, shape in specs:
        sz = _size(shape)
        out.append({"name": name, "shape": list(shape), "offset": off, "size": sz})
        off += sz
    return {"entries": out, "used": off, "padded": off + (-off) % pad_multiple}


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed):
    """GPT-2-style init, residual-scaled output projections. -> flat f32[Np]."""
    specs = param_specs(cfg)
    key = jax.random.key(jnp.asarray(seed, dtype=jnp.uint32))
    keys = jax.random.split(key, len(specs))
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.layers)
    tree = {}
    for (name, shape), k in zip(specs, keys):
        base = name.split(".")[-1]
        if base.startswith("ln") or base in ("lnf_s",):
            tree[name] = jnp.ones(shape, jnp.float32) if name.endswith("_s") else jnp.zeros(shape, jnp.float32)
        elif name.endswith("_s"):
            tree[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b") or base in ("b1", "b2"):
            tree[name] = jnp.zeros(shape, jnp.float32)
        elif base in ("wo", "w2"):
            tree[name] = 0.02 * resid_scale * jax.random.normal(k, shape, jnp.float32)
        else:
            tree[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
    return pack(specs, tree, cfg.pad_multiple)


def init_lora(cfg: ModelConfig, seed):
    """LoRA init: A ~ N(0, 0.02), B = 0 (adapter starts as identity)."""
    specs = lora_specs(cfg)
    key = jax.random.key(jnp.asarray(seed, dtype=jnp.uint32))
    keys = jax.random.split(key, len(specs))
    tree = {}
    for (name, shape), k in zip(specs, keys):
        if name.endswith("A"):
            tree[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
        else:
            tree[name] = jnp.zeros(shape, jnp.float32)
    return pack(specs, tree, cfg.pad_multiple)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layernorm(x, s, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * s + b


def _proj(h, w, lora_a, lora_b, scale):
    out = h @ w
    if lora_a is not None:
        out = out + (h @ lora_a.T) @ lora_b.T * scale
    return out


def _lora_parts(cfg, lt, l, which):
    if lt is None:
        return None, None, 0.0
    scale = cfg.lora_alpha / max(cfg.lora_rank, 1)
    return lt[f"l{l}.lora_{which}A"], lt[f"l{l}.lora_{which}B"], scale


def forward(cfg: ModelConfig, pt, tokens, pad_len, lt=None, use_pallas=True, collect_kv=False):
    """Teacher-forced forward.

    pt: unpacked param dict; tokens: i32[B, S]; pad_len: i32[B];
    lt: unpacked LoRA dict or None.
    Returns logits f32[B, S, V]; with collect_kv also per-layer K/V
    [L, B, H, S, dh] for prefill cache seeding.
    """
    B, S = tokens.shape
    H, dh = cfg.heads, cfg.d_head
    pos = jnp.clip(jnp.arange(S)[None, :] - pad_len[:, None], 0, cfg.seq_len - 1)
    x = pt["tok_emb"][tokens] + jnp.take(pt["pos_emb"], pos, axis=0)
    kvs = []
    attn = attention_pallas if use_pallas else (lambda q, k, v, p, *a: kref.attention_ref(q, k, v, p))
    for l in range(cfg.layers):
        h = _layernorm(x, pt[f"l{l}.ln1_s"], pt[f"l{l}.ln1_b"])
        qa, qb, qs = _lora_parts(cfg, lt, l, "q")
        va, vb, vs = _lora_parts(cfg, lt, l, "v")
        q = _proj(h, pt[f"l{l}.wq"], qa, qb, qs)
        k = h @ pt[f"l{l}.wk"]
        v = _proj(h, pt[f"l{l}.wv"], va, vb, vs)
        q = q.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
        k = k.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
        if collect_kv:
            kvs.append((k, v))
        o = attn(q, k, v, pad_len, cfg.attn_block, cfg.attn_block)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.d_model)
        x = x + o @ pt[f"l{l}.wo"]
        h2 = _layernorm(x, pt[f"l{l}.ln2_s"], pt[f"l{l}.ln2_b"])
        x = x + jax.nn.gelu(h2 @ pt[f"l{l}.w1"] + pt[f"l{l}.b1"]) @ pt[f"l{l}.w2"] + pt[f"l{l}.b2"]
    h = _layernorm(x, pt["lnf_s"], pt["lnf_b"])
    logits = h @ pt["tok_emb"].T
    if collect_kv:
        ks = jnp.stack([k for k, _ in kvs])  # [L, B, H, S, dh]
        vs = jnp.stack([v for _, v in kvs])
        return logits, ks, vs
    return logits


# ---------------------------------------------------------------------------
# KV-cache decode (inference phase)
# ---------------------------------------------------------------------------
#
# The decode path is split into two programs so the Rust driver can run
# slot-based continuous batching with early exit:
#
#   * ``prefill``      — teacher-forced pass over the prompts, returns the
#                        seeded KV caches plus the last prompt logits.
#   * ``decode_chunk`` — scan over a static chunk of ``C`` tokens with the
#                        caches carried across calls; per-row positions and
#                        per-row done flags let rows at different depths
#                        share one batch (refilled slots restart at step 0
#                        while their neighbours keep decoding).
#
# RNG ownership is per-row: each row folds a counter-based stream from its
# own seed (``fold_in(key(seed_b), step_b)``), so sampled tokens are
# bit-invariant to chunk size, slot assignment, refill order and batch
# composition — the property the Rust goldens pin.


def _decode_step(cfg: ModelConfig, pt, lt, cache_k, cache_v, tok, pos, pad_len):
    """One autoregressive step at per-row absolute positions ``pos``.

    cache_k/v: f32[L, B, H, T, dh]; tok: i32[B]; pos: i32[B].
    Rows with ``pos >= T`` write nothing (the one-hot scatter misses) —
    overshooting rows are masked out by the caller's done flag.
    Returns (logits[B, V], cache_k, cache_v).
    """
    B = tok.shape[0]
    H, dh, T = cfg.heads, cfg.d_head, cfg.seq_len
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    p = jnp.clip(pos - pad_len, 0, cfg.seq_len - 1)
    x = pt["tok_emb"][tok] + pt["pos_emb"][p]
    kpos = jnp.arange(T)
    hit = kpos[None, :] == pos[:, None]  # [B, T] one-hot write position
    visible = (kpos[None, :] <= pos[:, None]) & (kpos[None, :] >= pad_len[:, None])  # [B, T]
    for l in range(cfg.layers):
        h = _layernorm(x, pt[f"l{l}.ln1_s"], pt[f"l{l}.ln1_b"])
        qa, qb, qs = _lora_parts(cfg, lt, l, "q")
        va, vb, vs = _lora_parts(cfg, lt, l, "v")
        q = _proj(h, pt[f"l{l}.wq"], qa, qb, qs).reshape(B, H, dh)
        k = (h @ pt[f"l{l}.wk"]).reshape(B, H, dh)
        v = _proj(h, pt[f"l{l}.wv"], va, vb, vs).reshape(B, H, dh)
        ck = jnp.where(hit[:, None, :, None], k[:, :, None, :], cache_k[l])
        cv = jnp.where(hit[:, None, :, None], v[:, :, None, :], cache_v[l])
        cache_k = cache_k.at[l].set(ck)
        cache_v = cache_v.at[l].set(cv)
        s = jnp.einsum("bhd,bhtd->bht", q, ck) * scale
        s = jnp.where(visible[:, None, :], s, NEG)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bht,bhtd->bhd", a, cv).reshape(B, cfg.d_model)
        x = x + o @ pt[f"l{l}.wo"]
        h2 = _layernorm(x, pt[f"l{l}.ln2_s"], pt[f"l{l}.ln2_b"])
        x = x + jax.nn.gelu(h2 @ pt[f"l{l}.w1"] + pt[f"l{l}.b1"]) @ pt[f"l{l}.w2"] + pt[f"l{l}.b2"]
    h = _layernorm(x, pt["lnf_s"], pt["lnf_b"])
    return h @ pt["tok_emb"].T, cache_k, cache_v


def prefill(cfg: ModelConfig, flat, prompts, pad_len, lora_flat=None, use_pallas=True):
    """Prompt pass: seed the KV caches and return the last prompt logits.

    prompts: i32[B, P] left-padded; pad_len: i32[B].
    Returns (cache_k f32[L,B,H,T,dh], cache_v, logits f32[B, V]).
    """
    pt = unpack(param_specs(cfg), flat)
    lt = unpack(lora_specs(cfg), lora_flat) if lora_flat is not None else None
    B, P = prompts.shape
    T = cfg.seq_len
    H, dh, L = cfg.heads, cfg.d_head, cfg.layers
    logits_p, ks, vs = forward(cfg, pt, prompts, pad_len, lt, use_pallas, collect_kv=True)
    cache_k = jnp.zeros((L, B, H, T, dh), jnp.float32)
    cache_v = jnp.zeros((L, B, H, T, dh), jnp.float32)
    cache_k = cache_k.at[:, :, :, :P, :].set(ks)
    cache_v = cache_v.at[:, :, :, :P, :].set(vs)
    return cache_k, cache_v, logits_p[:, P - 1, :]


def merge_slots(cache_k_live, cache_v_live, logits_live, cache_k_new, cache_v_new, logits_new, admit):
    """Slot-admission merge, on device: slots with ``admit != 0`` take the
    fresh prefill state, the rest keep the carried decode state.

    cache_*: f32[L, B, H, T, dh]; logits_*: f32[B, V]; admit: i32[B].
    Keeps the continuous-batching driver free of host cache round-trips.
    """
    m = admit != 0
    ck = jnp.where(m[None, :, None, None, None], cache_k_new, cache_k_live)
    cv = jnp.where(m[None, :, None, None, None], cache_v_new, cache_v_live)
    lg = jnp.where(m[:, None], logits_new, logits_live)
    return ck, cv, lg


def prefill_shared(cfg: ModelConfig, flat, prompts, pad_len, lora_flat=None, use_pallas=True):
    """``prefill`` that returns its prompt state twice: a working copy for
    decode plus an immutable snapshot for later sibling admissions.

    Group-shared prompt KV runs the prompt pass **once** per group: the
    driver fills every slot of the prefill batch with the group's (single)
    prompt, keeps the snapshot triple on device, and admits sibling rows by
    replicating it (``share_slots``) instead of re-running prefill. The
    duplication exists because the caller consumes the working state into
    ``decode_chunk`` calls while the snapshot must survive them.

    Returns (cache_k, cache_v, logits, snap_k, snap_v, snap_logits).
    """
    cache_k, cache_v, logits = prefill(cfg, flat, prompts, pad_len, lora_flat, use_pallas)
    return cache_k, cache_v, logits, cache_k, cache_v, logits


def share_slots(cache_k_live, cache_v_live, logits_live, cache_k_snap, cache_v_snap, logits_snap, admit):
    """Sibling admission from a shared prompt snapshot, on device: slots
    with ``admit != 0`` take the snapshot's prompt state (every snapshot
    slot holds the same group prompt), and the snapshot passes through
    unchanged so the next sibling can reuse it — ``merge_slots``
    generalized to a source that must outlive the merge.

    cache_*: f32[L, B, H, T, dh]; logits_*: f32[B, V]; admit: i32[B].
    Returns (cache_k, cache_v, logits, snap_k, snap_v, snap_logits).
    """
    ck, cv, lg = merge_slots(
        cache_k_live, cache_v_live, logits_live, cache_k_snap, cache_v_snap, logits_snap, admit
    )
    return ck, cv, lg, cache_k_snap, cache_v_snap, logits_snap


def _sample_rows(seeds_u32, step, logits, temperature):
    """Per-row counter-based sampling: fold_in(key(seed_b), step_b).

    seeds_u32: u32[B]; step: i32[B]; logits: f32[B, V].
    Returns (tok i32[B], lp f32[B]) — the sampled (or greedy) token and its
    temperature-1 log-prob. Independent of batch composition by design.
    """
    temp = jnp.maximum(temperature, 1e-6)

    def row(seed, t, logit_row):
        k = jax.random.fold_in(jax.random.key(seed), t)
        return jax.random.categorical(k, logit_row / temp).astype(jnp.int32)

    sampled = jax.vmap(row)(seeds_u32, step, logits)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok = jnp.where(temperature > 0.0, sampled, greedy)
    lp_all = jax.nn.log_softmax(logits, axis=-1)
    lp = jnp.take_along_axis(lp_all, tok[:, None], axis=1)[:, 0]
    return tok, lp


def decode_chunk(cfg: ModelConfig, chunk, flat, cache_k, cache_v, logits, seeds, step, done, pad_len, temperature, lora_flat=None):
    """Decode ``chunk`` tokens for every row, carrying caches across calls.

    cache_k/v: f32[L,B,H,T,dh]; logits: f32[B,V] (next-token logits);
    seeds: i32[B] per-row RNG seeds; step: i32[B] decode steps executed
    per row (>= tokens generated: it advances past EOS too; the mask is
    the generated-token count); done: i32[B] 0/1; pad_len: i32[B];
    temperature: f32 scalar.

    Returns (tokens i32[B,C], logprobs f32[B,C], mask f32[B,C], cache_k,
    cache_v, logits, step, done). Rows that are done (or have reached the
    generation budget G) emit PAD/0/0 and stop touching their cache.
    """
    pt = unpack(param_specs(cfg), flat)
    lt = unpack(lora_specs(cfg), lora_flat) if lora_flat is not None else None
    P, G = cfg.prompt_len, cfg.gen_len
    seeds_u32 = seeds.astype(jnp.uint32)

    def one(carry, _):
        cache_k, cache_v, logits, step, done = carry
        done = done | (step >= G).astype(done.dtype)
        tok, lp = _sample_rows(seeds_u32, step, logits, temperature)
        live = done == 0
        tok = jnp.where(live, tok, V.PAD)
        lp = jnp.where(live, lp, 0.0)
        mask = jnp.where(live, 1.0, 0.0)
        done = done | (tok == V.EOS).astype(done.dtype)
        logits2, cache_k, cache_v = _decode_step(cfg, pt, lt, cache_k, cache_v, tok, P + step, pad_len)
        return (cache_k, cache_v, logits2, step + 1, done), (tok, lp, mask)

    init = (cache_k, cache_v, logits, step, done)
    (cache_k, cache_v, logits, step, done), (toks, lps, masks) = jax.lax.scan(
        one, init, None, length=chunk
    )
    return toks.T, lps.T, masks.T, cache_k, cache_v, logits, step, done


def rollout(cfg: ModelConfig, flat, prompts, pad_len, seeds, temperature, lora_flat=None, use_pallas=True, chunk=None):
    """The inference phase: sample ``B_r`` rollouts with a KV cache.

    Composed of ``prefill`` + ``decode_chunk`` calls (``chunk`` defaults to
    the full generation budget G, i.e. one monolithic chunk) so the
    monolithic program and the Rust chunked driver share one computation
    per step — any chunking produces bit-identical streams.

    prompts: i32[B, P] left-padded; pad_len: i32[B]; seeds: i32[B] per-row
    RNG seeds; temperature: f32 scalar — > 0 samples, <= 0 decodes greedily
    (the eval path reuses this).

    Returns:
      tokens   i32[B, T]  prompt + generation (PAD after EOS)
      logprobs f32[B, G]  behaviour log-probs of sampled tokens (temp-1
                          distribution — the π_fixed of the GRPO ratio)
      gen_mask f32[B, G]  1.0 through the EOS token, 0.0 after
      gen_len  i32[B]     number of generated tokens incl. EOS
    """
    B, _ = prompts.shape
    G = cfg.gen_len
    chunk = G if chunk is None else chunk
    cache_k, cache_v, logits = prefill(cfg, flat, prompts, pad_len, lora_flat, use_pallas)
    step = jnp.zeros((B,), jnp.int32)
    done = jnp.zeros((B,), jnp.int32)
    toks, lps, masks = [], [], []
    g = 0
    while g < G:
        c = min(chunk, G - g)
        tk, lp, mk, cache_k, cache_v, logits, step, done = decode_chunk(
            cfg, c, flat, cache_k, cache_v, logits, seeds, step, done, pad_len, temperature, lora_flat
        )
        toks.append(tk)
        lps.append(lp)
        masks.append(mk)
        g += c
    gen_tokens = jnp.concatenate(toks, axis=1)  # [B, G]
    logprobs = jnp.concatenate(lps, axis=1)
    gen_mask = jnp.concatenate(masks, axis=1)
    tokens = jnp.concatenate([prompts, gen_tokens], axis=1)
    gen_len = jnp.sum(gen_mask, axis=1).astype(jnp.int32)
    return tokens, logprobs, gen_mask, gen_len


# ---------------------------------------------------------------------------
# Log-probs / losses (policy-update phase)
# ---------------------------------------------------------------------------


def gen_logprobs(cfg: ModelConfig, flat, tokens, pad_len, lora_flat=None, use_pallas=True):
    """Teacher-forced log-probs of the generated region: -> f32[B, G].

    Position P-1 .. T-2 logits predict tokens at P .. T-1.
    """
    pt = unpack(param_specs(cfg), flat)
    lt = unpack(lora_specs(cfg), lora_flat) if lora_flat is not None else None
    B, T = tokens.shape
    P, G = cfg.prompt_len, cfg.gen_len
    logits = forward(cfg, pt, tokens, pad_len, lt, use_pallas)[:, P - 1 : T - 1, :]
    labels = tokens[:, P:T]
    lp_fn = logprob_pallas if use_pallas else (lambda lg, lb: kref.logprob_ref(lg, lb))
    lp = lp_fn(logits.reshape(B * G, cfg.vocab), labels.reshape(B * G))
    return lp.reshape(B, G)


def grpo_grad(cfg: ModelConfig, trainable, tokens, pad_len, gen_mask, old_lp, adv, ref_lp, kl_coef, base=None, use_pallas=True):
    """One policy-update micro-batch: GRPO-PODS objective fwd+bwd.

    trainable: the flat vector being optimised (full params, or the LoRA
    vector when ``base`` is the frozen full-parameter vector).
    Returns (grads[like trainable], loss, clip_frac, kl).
    Gradient *accumulation across micro-batches happens in Rust* — this is
    deliberately a single micro-batch so GRPO-GA's extra sequential steps
    are real work the coordinator schedules.
    """
    lora_mode = base is not None

    def loss_fn(tr):
        if lora_mode:
            new_lp = gen_logprobs(cfg, base, tokens, pad_len, lora_flat=tr, use_pallas=use_pallas)
        else:
            new_lp = gen_logprobs(cfg, tr, tokens, pad_len, use_pallas=use_pallas)
        if use_pallas:
            obj_rows, clip_rows = grpo_objective(new_lp, old_lp, adv, gen_mask, cfg.clip_eps)
        else:
            obj_rows, clip_rows = kref.grpo_loss_ref(new_lp, old_lp, adv, gen_mask, cfg.clip_eps)
        obj = jnp.mean(obj_rows)
        # k3 KL estimator vs the reference policy (Table 2: only setting (b)
        # has kl_coef > 0; Rust passes zeros for ref_lp otherwise).
        delta = ref_lp - new_lp
        kl_tok = (jnp.exp(delta) - delta - 1.0) * gen_mask
        kl = jnp.sum(kl_tok) / jnp.maximum(jnp.sum(gen_mask), 1.0)
        loss = -obj + kl_coef * kl
        clip_frac = jnp.mean(clip_rows)
        return loss, (clip_frac, kl)

    (loss, (clip_frac, kl)), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
    return grads, loss, clip_frac, kl


def sft_loss(cfg: ModelConfig, flat, tokens, pad_len, loss_mask, use_pallas=True):
    """Next-token cross-entropy over masked positions (full sequence)."""
    pt = unpack(param_specs(cfg), flat)
    B, T = tokens.shape
    logits = forward(cfg, pt, tokens, pad_len, None, use_pallas)[:, : T - 1, :]
    labels = tokens[:, 1:T]
    mask = loss_mask[:, 1:T]
    lp_fn = logprob_pallas if use_pallas else (lambda lg, lb: kref.logprob_ref(lg, lb))
    lp = lp_fn(logits.reshape(B * (T - 1), cfg.vocab), labels.reshape(-1)).reshape(B, T - 1)
    return -jnp.sum(lp * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def sft_step(cfg: ModelConfig, flat, m, v, step, tokens, pad_len, loss_mask, lr, use_pallas=True):
    """Fused SFT step: CE grad + AdamW apply. -> (params', m', v', loss)."""
    loss, grads = jax.value_and_grad(lambda f: sft_loss(cfg, f, tokens, pad_len, loss_mask, use_pallas))(flat)
    if use_pallas:
        p2, m2, v2 = adamw_update(
            flat, grads, m, v, step, lr=lr, b1=cfg.adam_b1, b2=cfg.adam_b2, eps=cfg.adam_eps, wd=cfg.weight_decay
        )
    else:
        p2, m2, v2 = kref.adamw_ref(flat, grads, m, v, step, lr, cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.weight_decay)
    return p2, m2, v2, loss


def apply_update(cfg: ModelConfig, flat, m, v, step, grads, lr, use_pallas=True):
    """AdamW apply on accumulated grads. -> (params', m', v')."""
    if use_pallas:
        return adamw_update(
            flat, grads, m, v, step, lr=lr, b1=cfg.adam_b1, b2=cfg.adam_b2, eps=cfg.adam_eps, wd=cfg.weight_decay
        )
    return kref.adamw_ref(flat, grads, m, v, step, lr, cfg.adam_b1, cfg.adam_b2, cfg.adam_eps, cfg.weight_decay)
