"""Shared token vocabulary for the synthetic RLVR tasks.

This is the single Python-side source of truth; it is emitted verbatim into
``artifacts/<profile>/meta.json`` and cross-checked against the Rust
tokenizer (``rust/src/tasks/tokenizer.rs``) by tests on both sides.

The XML reasoning tags of the paper's reward model (§A.1) are single tokens
so that short sequence budgets still leave room for actual reasoning.
"""

PAD = 0
BOS = 1
EOS = 2
NL = 3
THINK_OPEN = 4
THINK_CLOSE = 5
ANSWER_OPEN = 6
ANSWER_CLOSE = 7

# token id -> display string
TOKENS = [
    "<pad>",  # 0
    "<bos>",  # 1
    "<eos>",  # 2
    "\n",  # 3
    "<think>",  # 4
    "</think>",  # 5
    "<answer>",  # 6
    "</answer>",  # 7
    "0", "1", "2", "3", "4", "5", "6", "7", "8", "9",  # 8..17
    "+",  # 18
    "-",  # 19
    "*",  # 20
    "=",  # 21
    "(",  # 22
    ")",  # 23
    "?",  # 24
    ":",  # 25
    " ",  # 26
    "A",  # 27
    "B",  # 28
    "C",  # 29
    "D",  # 30
    "x",  # 31
    "^",  # 32
    "%",  # 33
    ",",  # 34
    ";",  # 35
    ".",  # 36
    "/",  # 37
    "|",  # 38
    "Q",  # 39
]

DIGIT0 = 8  # token id of "0"

# Vocab is padded to a multiple of 16 so kernel tiles divide it evenly.
VOCAB_SIZE = 48

assert len(TOKENS) <= VOCAB_SIZE

STR_TO_ID = {s: i for i, s in enumerate(TOKENS)}


def encode(text_tokens):
    """Encode a list of display strings to token ids."""
    return [STR_TO_ID[t] for t in text_tokens]


def vocab_meta():
    """The vocabulary block written into meta.json."""
    return {
        "tokens": TOKENS,
        "vocab_size": VOCAB_SIZE,
        "pad": PAD,
        "bos": BOS,
        "eos": EOS,
        "nl": NL,
        "think_open": THINK_OPEN,
        "think_close": THINK_CLOSE,
        "answer_open": ANSWER_OPEN,
        "answer_close": ANSWER_CLOSE,
        "digit0": DIGIT0,
    }
