"""AOT compile path: lower every L2 program to HLO text + meta.json.

This is the only place Python touches the system: ``make artifacts`` runs it
once, and the Rust coordinator is self-contained afterwards.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each *profile* (model dims × batch shapes × LoRA mode) produces one artifact
directory::

    artifacts/<profile>/
      init.hlo.txt      (seed)                                    -> (params)
      sft.hlo.txt       (params,m,v,step,tokens,pad,mask,lr)      -> (params,m,v,loss)
      rollout.hlo.txt   (params,[lora],prompts,pad,seeds,temp)    -> (tokens,logprobs,gen_mask,gen_len)
      prefill.hlo.txt   (params,[lora],prompts,pad)               -> (cache_k,cache_v,logits)
      prefill_shared.hlo.txt
                        (params,[lora],prompts,pad)               -> (cache_k,cache_v,logits,
                                                                      snap_k,snap_v,snap_logits)
      decode_chunk<C>.hlo.txt
                        (params,[lora],cache_k,cache_v,logits,seeds,step,done,pad,temp)
                                                                  -> (tokens,logprobs,mask,cache_k,cache_v,logits,step,done)
      grad.hlo.txt      (train,[base],tokens,pad,mask,old_lp,adv,ref_lp,kl) -> (grads,loss,clip_frac,kl)
      update.hlo.txt    (train,m,v,step,grads,lr)                 -> (train,m,v)
      score.hlo.txt     (params,[lora],tokens,pad)                -> (logprobs)
      meta.json         dims, vocab, param offset table, program signatures,
                        decode_chunks (the lowered chunk sizes)

``rollout`` is the monolithic reference (one chunk of G); the Rust rollout
engine drives ``prefill`` + ``decode_chunk<C>`` as a slot-based continuous
batcher with early exit. RNG is per-row (``seeds`` i32[B], counter-based
streams), so both paths sample bit-identical tokens. The greedy eval path
reuses the chunked programs with temperature <= 0.

``prefill_shared`` / ``admit_share`` are the group-shared prompt-KV path:
one prompt pass per group returns its state twice (working + snapshot) and
sibling rows are admitted by replicating the on-device snapshot instead of
re-running prefill — streams stay bit-identical because prefill is per-row
independent and the prompt region of the cache is immutable during decode.
"""

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import vocab as V

f32 = jnp.float32
i32 = jnp.int32
u32 = jnp.uint32


# One profile per (model size, shapes, tuning mode). Table 1 settings map to
# these via configs/*.toml on the Rust side.
PROFILES = {
    # fast-compiling tiny model for Rust integration tests
    "micro": M.ModelConfig(
        d_model=32, layers=2, heads=2, d_ff=64, seq_len=24, prompt_len=8,
        rollout_batch=4, update_batch=2, pad_multiple=256, attn_block=8,
    ),
    # the workhorse: settings (a)-(f) at laptop scale, full-parameter
    "base": M.ModelConfig(
        d_model=128, layers=4, heads=4, d_ff=512, seq_len=96, prompt_len=32,
        rollout_batch=16, update_batch=8, pad_multiple=4096, attn_block=32,
    ),
    # LoRA variant of base (settings a-d train adapters on a frozen base)
    "lora": M.ModelConfig(
        d_model=128, layers=4, heads=4, d_ff=512, seq_len=96, prompt_len=32,
        rollout_batch=16, update_batch=8, pad_multiple=4096, attn_block=32,
        lora_rank=16, lora_alpha=16.0,
    ),
    # ~99M-parameter config for the e2e_100m composition proof
    "big": M.ModelConfig(
        d_model=768, layers=14, heads=12, d_ff=3072, seq_len=64, prompt_len=24,
        rollout_batch=4, update_batch=2, pad_multiple=65536, attn_block=8,
    ),
}


def decode_chunk_sizes(cfg: M.ModelConfig):
    """Chunk sizes lowered per profile: {1, 4, 16} clipped to G, plus G
    itself (the monolithic-equivalent chunk)."""
    return sorted({c for c in (1, 4, 16) if c <= cfg.gen_len} | {cfg.gen_len})


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args, outs):
    def fmt(x):
        return {"dtype": str(x.dtype), "shape": list(x.shape)}

    return {"inputs": [dict(name=n, **fmt(a)) for n, a in args], "outputs": [dict(name=n, **fmt(o)) for n, o in outs]}


def build_programs(cfg: M.ModelConfig):
    """Return {name: (fn, [(argname, ShapeDtypeStruct)])} for one profile."""
    Np = M.param_count(cfg)
    Nl = M.lora_count(cfg) if cfg.lora_rank else 0
    Nt = Nl if cfg.lora_rank else Np  # trainable vector length
    Br, Bu = cfg.rollout_batch, cfg.update_batch
    T, P, G = cfg.seq_len, cfg.prompt_len, cfg.gen_len
    s = jax.ShapeDtypeStruct
    lora = cfg.lora_rank > 0

    progs = {}

    if not lora:
        progs["init"] = (
            lambda seed: (M.init_params(cfg, seed),),
            [("seed", s((), u32))],
            ["params"],
        )
        progs["sft"] = (
            lambda p, m, v, step, toks, pad, mask, lr: M.sft_step(cfg, p, m, v, step, toks, pad, mask, lr),
            [
                ("params", s((Np,), f32)), ("m", s((Np,), f32)), ("v", s((Np,), f32)),
                ("step", s((), i32)), ("tokens", s((Bu, T), i32)), ("pad_len", s((Bu,), i32)),
                ("loss_mask", s((Bu, T), f32)), ("lr", s((), f32)),
            ],
            ["params", "m", "v", "loss"],
        )
    else:
        progs["init"] = (
            lambda seed: (M.init_lora(cfg, seed),),
            [("seed", s((), u32))],
            ["lora"],
        )

    # decode-path shapes shared by prefill / decode_chunk
    L, H, dh, Vv = cfg.layers, cfg.heads, cfg.d_head, cfg.vocab
    cache = s((L, Br, H, T, dh), f32)

    if lora:
        progs["rollout"] = (
            lambda p, lo, pr, pad, seeds, temp: M.rollout(cfg, p, pr, pad, seeds, temp, lora_flat=lo),
            [
                ("params", s((Np,), f32)), ("lora", s((Nl,), f32)),
                ("prompts", s((Br, P), i32)), ("pad_len", s((Br,), i32)),
                ("seeds", s((Br,), i32)), ("temperature", s((), f32)),
            ],
            ["tokens", "logprobs", "gen_mask", "gen_len"],
        )
        progs["prefill"] = (
            lambda p, lo, pr, pad: M.prefill(cfg, p, pr, pad, lora_flat=lo),
            [
                ("params", s((Np,), f32)), ("lora", s((Nl,), f32)),
                ("prompts", s((Br, P), i32)), ("pad_len", s((Br,), i32)),
            ],
            ["cache_k", "cache_v", "logits"],
        )
        progs["prefill_shared"] = (
            lambda p, lo, pr, pad: M.prefill_shared(cfg, p, pr, pad, lora_flat=lo),
            [
                ("params", s((Np,), f32)), ("lora", s((Nl,), f32)),
                ("prompts", s((Br, P), i32)), ("pad_len", s((Br,), i32)),
            ],
            ["cache_k", "cache_v", "logits", "snap_k", "snap_v", "snap_logits"],
        )
        for c in decode_chunk_sizes(cfg):
            progs[f"decode_chunk{c}"] = (
                (lambda c: lambda p, lo, ck, cv, lg, sd, st, dn, pad, temp: M.decode_chunk(
                    cfg, c, p, ck, cv, lg, sd, st, dn, pad, temp, lora_flat=lo
                ))(c),
                [
                    ("params", s((Np,), f32)), ("lora", s((Nl,), f32)),
                    ("cache_k", cache), ("cache_v", cache), ("logits", s((Br, Vv), f32)),
                    ("seeds", s((Br,), i32)), ("step", s((Br,), i32)), ("done", s((Br,), i32)),
                    ("pad_len", s((Br,), i32)), ("temperature", s((), f32)),
                ],
                ["tokens", "logprobs", "mask", "cache_k", "cache_v", "logits", "step", "done"],
            )
        progs["grad"] = (
            lambda tr, base, toks, pad, mask, olp, adv, rlp, klc: M.grpo_grad(
                cfg, tr, toks, pad, mask, olp, adv, rlp, klc, base=base
            ),
            [
                ("trainable", s((Nt,), f32)), ("base", s((Np,), f32)),
                ("tokens", s((Bu, T), i32)), ("pad_len", s((Bu,), i32)),
                ("gen_mask", s((Bu, G), f32)), ("old_lp", s((Bu, G), f32)),
                ("adv", s((Bu,), f32)), ("ref_lp", s((Bu, G), f32)), ("kl_coef", s((), f32)),
            ],
            ["grads", "loss", "clip_frac", "kl"],
        )
        progs["score"] = (
            lambda p, lo, toks, pad: (M.gen_logprobs(cfg, p, toks, pad, lora_flat=lo),),
            [
                ("params", s((Np,), f32)), ("lora", s((Nl,), f32)),
                ("tokens", s((Br, T), i32)), ("pad_len", s((Br,), i32)),
            ],
            ["logprobs"],
        )
    else:
        progs["rollout"] = (
            lambda p, pr, pad, seeds, temp: M.rollout(cfg, p, pr, pad, seeds, temp),
            [
                ("params", s((Np,), f32)),
                ("prompts", s((Br, P), i32)), ("pad_len", s((Br,), i32)),
                ("seeds", s((Br,), i32)), ("temperature", s((), f32)),
            ],
            ["tokens", "logprobs", "gen_mask", "gen_len"],
        )
        progs["prefill"] = (
            lambda p, pr, pad: M.prefill(cfg, p, pr, pad),
            [
                ("params", s((Np,), f32)),
                ("prompts", s((Br, P), i32)), ("pad_len", s((Br,), i32)),
            ],
            ["cache_k", "cache_v", "logits"],
        )
        progs["prefill_shared"] = (
            lambda p, pr, pad: M.prefill_shared(cfg, p, pr, pad),
            [
                ("params", s((Np,), f32)),
                ("prompts", s((Br, P), i32)), ("pad_len", s((Br,), i32)),
            ],
            ["cache_k", "cache_v", "logits", "snap_k", "snap_v", "snap_logits"],
        )
        for c in decode_chunk_sizes(cfg):
            progs[f"decode_chunk{c}"] = (
                (lambda c: lambda p, ck, cv, lg, sd, st, dn, pad, temp: M.decode_chunk(
                    cfg, c, p, ck, cv, lg, sd, st, dn, pad, temp
                ))(c),
                [
                    ("params", s((Np,), f32)),
                    ("cache_k", cache), ("cache_v", cache), ("logits", s((Br, Vv), f32)),
                    ("seeds", s((Br,), i32)), ("step", s((Br,), i32)), ("done", s((Br,), i32)),
                    ("pad_len", s((Br,), i32)), ("temperature", s((), f32)),
                ],
                ["tokens", "logprobs", "mask", "cache_k", "cache_v", "logits", "step", "done"],
            )
        progs["grad"] = (
            lambda tr, toks, pad, mask, olp, adv, rlp, klc: M.grpo_grad(
                cfg, tr, toks, pad, mask, olp, adv, rlp, klc
            ),
            [
                ("trainable", s((Nt,), f32)),
                ("tokens", s((Bu, T), i32)), ("pad_len", s((Bu,), i32)),
                ("gen_mask", s((Bu, G), f32)), ("old_lp", s((Bu, G), f32)),
                ("adv", s((Bu,), f32)), ("ref_lp", s((Bu, G), f32)), ("kl_coef", s((), f32)),
            ],
            ["grads", "loss", "clip_frac", "kl"],
        )
        progs["score"] = (
            lambda p, toks, pad: (M.gen_logprobs(cfg, p, toks, pad),),
            [
                ("params", s((Np,), f32)),
                ("tokens", s((Br, T), i32)), ("pad_len", s((Br,), i32)),
            ],
            ["logprobs"],
        )

    # slot-admission merge for the continuous-batching driver (no params)
    progs["admit_merge"] = (
        M.merge_slots,
        [
            ("cache_k_live", cache), ("cache_v_live", cache), ("logits_live", s((Br, Vv), f32)),
            ("cache_k_new", cache), ("cache_v_new", cache), ("logits_new", s((Br, Vv), f32)),
            ("admit", s((Br,), i32)),
        ],
        ["cache_k", "cache_v", "logits"],
    )

    # sibling admission from a group's shared prompt snapshot (no params):
    # like admit_merge, but the source state passes through for reuse
    progs["admit_share"] = (
        M.share_slots,
        [
            ("cache_k_live", cache), ("cache_v_live", cache), ("logits_live", s((Br, Vv), f32)),
            ("cache_k_snap", cache), ("cache_v_snap", cache), ("logits_snap", s((Br, Vv), f32)),
            ("admit", s((Br,), i32)),
        ],
        ["cache_k", "cache_v", "logits", "snap_k", "snap_v", "snap_logits"],
    )

    progs["update"] = (
        lambda tr, m, v, step, g, lr: M.apply_update(cfg, tr, m, v, step, g, lr),
        [
            ("trainable", s((Nt,), f32)), ("m", s((Nt,), f32)), ("v", s((Nt,), f32)),
            ("step", s((), i32)), ("grads", s((Nt,), f32)), ("lr", s((), f32)),
        ],
        ["trainable", "m", "v"],
    )
    return progs


def lower_profile(name: str, out_root: str, verbose=True):
    cfg = PROFILES[name]
    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)
    progs = build_programs(cfg)
    signatures = {}
    for pname, (fn, args, out_names) in progs.items():
        shapes = [a for _, a in args]
        lowered = jax.jit(fn).lower(*shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{pname}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *shapes)
        outs = outs if isinstance(outs, (tuple, list)) else (outs,)
        signatures[pname] = _sig(args, list(zip(out_names, outs)))
        if verbose:
            print(f"  {name}/{pname}: {len(text)} chars, {len(args)} in / {len(outs)} out")

    meta = {
        "profile": name,
        "config": dataclasses.asdict(cfg),
        "gen_len": cfg.gen_len,
        "decode_chunks": decode_chunk_sizes(cfg),
        "param_count": M.param_count(cfg),
        "lora_count": M.lora_count(cfg) if cfg.lora_rank else 0,
        "trainable_count": M.lora_count(cfg) if cfg.lora_rank else M.param_count(cfg),
        "param_spec": M.spec_meta(M.param_specs(cfg), cfg.pad_multiple),
        "lora_spec": M.spec_meta(M.lora_specs(cfg), cfg.pad_multiple) if cfg.lora_rank else None,
        "vocab": V.vocab_meta(),
        "programs": signatures,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return out_dir


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--profiles", default="micro,base,lora")
    args = ap.parse_args()
    for p in args.profiles.split(","):
        p = p.strip()
        if not p:
            continue
        if p not in PROFILES:
            raise SystemExit(f"unknown profile {p!r}; have {sorted(PROFILES)}")
        print(f"lowering profile {p} ...")
        lower_profile(p, args.out)
    print("done.")


if __name__ == "__main__":
    main()
