"""Fused GRPO clipped-surrogate Pallas kernel.

Computes the per-rollout objective of the GRPO-PODS loss (paper Eq. 2):

    obj_i = (1 / |o_i|) * sum_t min(r_t * a_i, clip(r_t, 1-eps, 1+eps) * a_i)

in a single pass over ``[B, G]`` token log-prob pairs, fusing ratio,
clipping, advantage broadcast, the length mask and the per-rollout token
mean.  The naive jnp formulation materialises six ``[B, G]`` intermediates;
this kernel keeps one tile resident in VMEM.

Also emits the per-rollout clipped-token fraction (a standard PPO/GRPO
telemetry signal the Rust coordinator logs).

The ``custom_vjp`` backward is itself a Pallas kernel: the surrogate is
piecewise-linear in the ratio, so

    d obj_i / d new_lp_{i,t} = mask * r_t * a_i * active / |o_i|

where ``active`` selects whichever branch the ``min`` picked, with the
clipped branch contributing gradient only while the ratio is inside the
clip interval (the "slow to adopt, quick to abandon" asymmetry).

Grid: 1-D over B-blocks; each block reduces its full G extent (G is the
generation budget, ≤ a few hundred — one VMEM tile).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import grpo_loss_ref

DEFAULT_BLK_B = 8


def _fwd_kernel(nlp_ref, olp_ref, adv_ref, mask_ref, obj_ref, clip_ref, *, eps):
    nlp = nlp_ref[...]
    olp = olp_ref[...]
    mask = mask_ref[...]
    a = adv_ref[...][:, None]
    ratio = jnp.exp(nlp - olp)
    unclipped = ratio * a
    clipped = jnp.clip(ratio, 1.0 - eps, 1.0 + eps) * a
    tok = jnp.minimum(unclipped, clipped) * mask
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    obj_ref[...] = jnp.sum(tok, axis=1) / cnt
    clip_ref[...] = jnp.sum(jnp.where(clipped < unclipped, mask, 0.0), axis=1) / cnt


def _bwd_kernel(nlp_ref, olp_ref, adv_ref, mask_ref, g_ref, dnlp_ref, *, eps):
    nlp = nlp_ref[...]
    olp = olp_ref[...]
    mask = mask_ref[...]
    a = adv_ref[...][:, None]
    g = g_ref[...][:, None]
    ratio = jnp.exp(nlp - olp)
    unclipped = ratio * a
    clipped = jnp.clip(ratio, 1.0 - eps, 1.0 + eps) * a
    # min() picks the unclipped branch (grad = r*a) or the clipped branch
    # (grad = r*a while inside the interval, 0 once saturated).
    inside = (ratio > 1.0 - eps) & (ratio < 1.0 + eps)
    active = jnp.where(unclipped <= clipped, 1.0, jnp.where(inside, 1.0, 0.0))
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)[:, None]
    dnlp_ref[...] = g * mask * ratio * a * active / cnt


def _pad_b(x, blk):
    b = x.shape[0]
    pad = (-b) % blk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, b


def _call_fwd(new_lp, old_lp, adv, mask, eps, blk_b):
    nlp, b0 = _pad_b(new_lp, blk_b)
    olp, _ = _pad_b(old_lp, blk_b)
    a, _ = _pad_b(adv, blk_b)
    mk, _ = _pad_b(mask, blk_b)
    bp, g = nlp.shape
    kernel = functools.partial(_fwd_kernel, eps=eps)
    obj, clip_frac = pl.pallas_call(
        kernel,
        grid=(bp // blk_b,),
        in_specs=[
            pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
            pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
            pl.BlockSpec((blk_b,), lambda i: (i,)),
            pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_b,), lambda i: (i,)),
            pl.BlockSpec((blk_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((bp,), jnp.float32),
        ],
        interpret=True,
    )(nlp, olp, a, mk)
    return obj[:b0], clip_frac[:b0]


def _call_bwd(new_lp, old_lp, adv, mask, g_obj, eps, blk_b):
    nlp, b0 = _pad_b(new_lp, blk_b)
    olp, _ = _pad_b(old_lp, blk_b)
    a, _ = _pad_b(adv, blk_b)
    mk, _ = _pad_b(mask, blk_b)
    gg, _ = _pad_b(g_obj, blk_b)
    bp, g = nlp.shape
    kernel = functools.partial(_bwd_kernel, eps=eps)
    dnlp = pl.pallas_call(
        kernel,
        grid=(bp // blk_b,),
        in_specs=[
            pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
            pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
            pl.BlockSpec((blk_b,), lambda i: (i,)),
            pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
            pl.BlockSpec((blk_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk_b, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, g), jnp.float32),
        interpret=True,
    )(nlp, olp, a, mk, gg)
    return dnlp[:b0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def grpo_objective(new_lp, old_lp, adv, mask, eps, blk_b=DEFAULT_BLK_B):
    """Pallas fused GRPO surrogate: returns (obj[B], clip_frac[B]).

    Differentiable w.r.t. ``new_lp`` only (old_lp/adv/mask are data).
    Matches :func:`ref.grpo_loss_ref`.
    """
    return _call_fwd(new_lp, old_lp, adv, mask, eps, blk_b)


def _vjp_fwd(new_lp, old_lp, adv, mask, eps, blk_b):
    out = _call_fwd(new_lp, old_lp, adv, mask, eps, blk_b)
    return out, (new_lp, old_lp, adv, mask)


def _vjp_bwd(eps, blk_b, res, cotangents):
    new_lp, old_lp, adv, mask = res
    g_obj, _g_clip = cotangents  # clip_frac is telemetry: no gradient
    dnlp = _call_bwd(new_lp, old_lp, adv, mask, g_obj, eps, blk_b)
    return dnlp, None, None, None


grpo_objective.defvjp(_vjp_fwd, _vjp_bwd)


def grpo_objective_reference(new_lp, old_lp, adv, mask, eps):
    """Oracle re-export for tests/benchmarks."""
    return grpo_loss_ref(new_lp, old_lp, adv, mask, eps)
