"""Fused AdamW Pallas kernel over the flat parameter vector.

The policy-update phase the paper calls memory-bound is dominated by
optimizer-state traffic: AdamW touches 4 full-parameter streams (p, g, m, v)
and writes 3.  A naive jnp AdamW issues ~10 separate elementwise HLO ops,
each re-streaming the vectors; this kernel fuses moment updates, bias
correction, decoupled weight decay and the parameter write into one pass.

Grid: 1-D over ``Np / blk`` contiguous blocks — pure VPU work, so the
BlockSpec simply maximises sequential HBM streams (64Ki f32 = 256 KiB per
block, 7 streams ≈ 1.75 MiB resident, comfortably inside a TPU core's
~16 MiB VMEM with double buffering).

The flat parameter vector is padded to a block multiple by the packer
(model.py), so no ragged handling is needed here.  The dynamic bias
correction factors (functions of the step counter) are computed outside and
broadcast in as two scalars; the hyperparameters are trace-time constants.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import adamw_ref

DEFAULT_BLK = 65536


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, c1_ref, c2_ref, po_ref, mo_ref, vo_ref, *, b1, b2, eps, wd):
    g = g_ref[...]
    p = p_ref[...]
    mn = b1 * m_ref[...] + (1.0 - b1) * g
    vn = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = mn * c1_ref[0]
    vhat = vn * c2_ref[0]
    po_ref[...] = p - lr_ref[0] * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    mo_ref[...] = mn
    vo_ref[...] = vn


def adamw_update(p, g, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.1, blk=DEFAULT_BLK):
    """Pallas fused AdamW: flat f32[Np] x4 + i32 step -> (p', m', v').

    ``Np`` must be a multiple of ``blk`` (the packer guarantees it).
    ``step`` is the 0-based step index and ``lr`` the learning rate — both
    may be traced values (the AOT artifacts take them as runtime inputs so
    the Rust side can schedule them without re-lowering). Matches
    :func:`ref.adamw_ref`.
    """
    import math

    n = p.shape[0]
    blk = min(blk, n)
    if n % blk:
        # Perf: pick the LARGEST divisor of n that fits the requested block
        # (multiples of gcd(n, blk)). The naive gcd choice (8192 for the
        # base profile's 811008) produced a 99-step grid; searching upward
        # finds 73728 -> 11 grid steps, ~9x fewer interpret-mode grid
        # iterations in the lowered HLO (EXPERIMENTS.md §Perf).
        unit = math.gcd(n, blk)
        best = unit
        k = 2
        while k * unit <= blk:
            if n % (k * unit) == 0:
                best = k * unit
            k += 1
        blk = best
    assert n % blk == 0, f"flat param length {n} not a multiple of block {blk}"
    t = (step + 1).astype(jnp.float32)
    c1 = (1.0 / (1.0 - b1**t)).reshape(1)
    c2 = (1.0 / (1.0 - b2**t)).reshape(1)
    lr_arr = jnp.asarray(lr, dtype=jnp.float32).reshape(1)
    kernel = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps, wd=wd)
    vec = pl.BlockSpec((blk,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=(n // blk,),
        in_specs=[vec, vec, vec, vec, scalar, scalar, scalar],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(p, g, m, v, lr_arr, c1, c2)


def adamw_reference(p, g, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.1):
    """Oracle re-export for tests/benchmarks."""
    return adamw_ref(p, g, m, v, step, lr, b1, b2, eps, wd)
