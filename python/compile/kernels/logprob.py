"""Fused log-softmax + label-gather Pallas kernel (the RLVR log-prob hot spot).

The policy-gradient path only ever needs the log-probability of the *sampled*
token, yet the naive jnp formulation materialises a full ``[B*T, V]``
log-softmax.  This kernel streams the vocabulary axis in VMEM-sized tiles
with an online (max, sum) accumulator — the TPU analogue of a warp-reduction
softmax — and gathers the label logit on the fly, so per-row VMEM is
``O(blk_r * v_tile)`` regardless of V.

Grid: ``(rows / blk_r, ceil(V / v_tile))``.  The three row-shaped outputs
(label-logit accumulator, running max, running sum) use index maps that
ignore the vocab grid axis, so their blocks persist across vocab tiles —
the standard Pallas accumulation idiom.

A ``custom_vjp`` makes the kernel differentiable: the forward also emits the
row logsumexp as a residual, so the backward is a *single*-pass Pallas kernel
``dlogits = g * (onehot(label) - exp(logits - lse))`` over the same grid.

TPU mapping (documented for the real-hardware port; we run interpret=True):
rows map to the VPU sublane axis, the vocab tile (512 f32 = 2KiB/row) streams
HBM→VMEM, and both passes are bandwidth-bound with perfect sequential reads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG, logprob_ref

DEFAULT_BLK_R = 64
DEFAULT_V_TILE = 512


def _fwd_kernel(logits_ref, labels_ref, lp_ref, lse_ref, m_ref, s_ref, *, v_total, v_tile, n_vt):
    j = pl.program_id(1)
    x = logits_ref[...]  # (blk_r, v_tile)
    col0 = j * v_tile
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = cols < v_total
    xm = jnp.where(valid, x, NEG)
    tile_max = jnp.max(xm, axis=1)  # (blk_r,)
    labels = labels_ref[...]  # (blk_r,)
    lbl_here = jnp.sum(jnp.where(cols == labels[:, None], x, 0.0), axis=1)
    has_lbl = jnp.where((labels >= col0) & (labels < col0 + v_tile), 1.0, 0.0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = tile_max
        s_ref[...] = jnp.sum(jnp.where(valid, jnp.exp(xm - tile_max[:, None]), 0.0), axis=1)
        lp_ref[...] = has_lbl * lbl_here

    @pl.when(j > 0)
    def _accum():
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, tile_max)
        p = jnp.where(valid, jnp.exp(xm - m_new[:, None]), 0.0)
        s_ref[...] = s_ref[...] * jnp.exp(m_old - m_new) + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        lp_ref[...] = lp_ref[...] + has_lbl * lbl_here

    @pl.when(j == n_vt - 1)
    def _finalize():
        lse = m_ref[...] + jnp.log(s_ref[...])
        lse_ref[...] = lse
        lp_ref[...] = lp_ref[...] - lse


def _bwd_kernel(logits_ref, labels_ref, lse_ref, g_ref, dlogits_ref, *, v_total, v_tile):
    j = pl.program_id(1)
    x = logits_ref[...]
    cols = j * v_tile + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = cols < v_total
    labels = labels_ref[...]
    lse = lse_ref[...]
    g = g_ref[...]
    onehot = jnp.where(cols == labels[:, None], 1.0, 0.0)
    softmax = jnp.where(valid, jnp.exp(x - lse[:, None]), 0.0)
    dlogits_ref[...] = g[:, None] * (onehot - softmax)


def _pad_rows(x, blk):
    r = x.shape[0]
    pad = (-r) % blk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, r


def _logprob_fwd_impl(logits, labels, blk_r, v_tile):
    rows, v_total = logits.shape
    logits_p, r0 = _pad_rows(logits, blk_r)
    labels_p, _ = _pad_rows(labels, blk_r)
    rp = logits_p.shape[0]
    n_rb = rp // blk_r
    n_vt = -(-v_total // v_tile)
    vp = n_vt * v_tile
    if vp != v_total:
        logits_p = jnp.concatenate(
            [logits_p, jnp.full((rp, vp - v_total), NEG, logits.dtype)], axis=1
        )
    kernel = functools.partial(_fwd_kernel, v_total=v_total, v_tile=v_tile, n_vt=n_vt)
    lp, lse, _m, _s = pl.pallas_call(
        kernel,
        grid=(n_rb, n_vt),
        in_specs=[
            pl.BlockSpec((blk_r, v_tile), lambda i, j: (i, j)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp,), jnp.float32),
            jax.ShapeDtypeStruct((rp,), jnp.float32),
            jax.ShapeDtypeStruct((rp,), jnp.float32),
            jax.ShapeDtypeStruct((rp,), jnp.float32),
        ],
        interpret=True,
    )(logits_p, labels_p)
    return lp[:r0], lse[:r0]


def _logprob_bwd_impl(logits, labels, lse, g, blk_r, v_tile):
    rows, v_total = logits.shape
    logits_p, r0 = _pad_rows(logits, blk_r)
    labels_p, _ = _pad_rows(labels, blk_r)
    lse_p, _ = _pad_rows(lse, blk_r)
    g_p, _ = _pad_rows(g, blk_r)
    rp = logits_p.shape[0]
    n_rb = rp // blk_r
    n_vt = -(-v_total // v_tile)
    vp = n_vt * v_tile
    if vp != v_total:
        logits_p = jnp.concatenate(
            [logits_p, jnp.full((rp, vp - v_total), NEG, logits.dtype)], axis=1
        )
    kernel = functools.partial(_bwd_kernel, v_total=v_total, v_tile=v_tile)
    dlogits = pl.pallas_call(
        kernel,
        grid=(n_rb, n_vt),
        in_specs=[
            pl.BlockSpec((blk_r, v_tile), lambda i, j: (i, j)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
            pl.BlockSpec((blk_r,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((blk_r, v_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, vp), jnp.float32),
        interpret=True,
    )(logits_p, labels_p, lse_p, g_p)
    return dlogits[:r0, :v_total]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def logprob(logits, labels, blk_r=DEFAULT_BLK_R, v_tile=DEFAULT_V_TILE):
    """Pallas fused token log-prob: f32[R, V], i32[R] -> f32[R].

    Matches :func:`ref.logprob_ref`; differentiable w.r.t. ``logits``.
    """
    lp, _ = _logprob_fwd_impl(logits, labels, blk_r, v_tile)
    return lp


def _vjp_fwd(logits, labels, blk_r, v_tile):
    lp, lse = _logprob_fwd_impl(logits, labels, blk_r, v_tile)
    return lp, (logits, labels, lse)


def _vjp_bwd(blk_r, v_tile, res, g):
    logits, labels, lse = res
    dlogits = _logprob_bwd_impl(logits, labels, lse, g, blk_r, v_tile)
    return dlogits, None


logprob.defvjp(_vjp_fwd, _vjp_bwd)


def logprob_reference(logits, labels):
    """Oracle re-export for tests/benchmarks."""
    return logprob_ref(logits, labels)
