"""Blocked causal attention Pallas kernel (flash-style online softmax).

This is the GPU→TPU hardware adaptation the paper's inference numbers imply
(DESIGN.md §9): where a CUDA flash-attention assigns a *threadblock* per
(batch, head) with K/V tiles staged through shared memory, here the schedule
is expressed with ``BlockSpec``s — the grid is ``(B*H, T/blk_q, T/blk_k)``,
Q/K/V tiles stream HBM→VMEM, and the online-softmax state (running max,
running denominator, unnormalised output) lives in revisited output blocks
whose index maps ignore the K axis.  The ``blk_q × blk_k`` score matmul and
the ``blk_k × dh`` value matmul are shaped to feed the MXU.

Masking implements the model's left-padding convention: key ``j`` is visible
to query ``i`` iff ``pad_len <= j <= i``.  Fully-masked query rows (padding
queries) degrade to uniform attention — finite values that the loss masks.

The ``custom_vjp`` backward recomputes standard attention in jnp (the
rematerialisation strategy of flash-attention backward) — the forward stays
on the Pallas path inside the lowered HLO, which is what the rollout/eval
artifacts execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG, attention_ref

DEFAULT_BLK_Q = 32
DEFAULT_BLK_K = 32


def _attn_kernel(q_ref, k_ref, v_ref, pad_ref, o_ref, m_ref, l_ref, *, blk_q, blk_k, nk, scale, t_real):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    q = q_ref[0]  # (blk_q, dh)
    k = k_ref[0]  # (blk_k, dh)
    v = v_ref[0]  # (blk_k, dh)
    pad = pad_ref[0]
    s = jnp.dot(q, k.T) * scale  # (blk_q, blk_k) — MXU tile
    qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    visible = (kpos <= qpos) & (kpos >= pad) & (kpos < t_real)
    s = jnp.where(visible, s, NEG)
    tile_max = jnp.max(s, axis=1)  # (blk_q,)

    @pl.when(kj == 0)
    def _init():
        p = jnp.exp(s - tile_max[:, None])
        m_ref[...] = tile_max[None]
        l_ref[...] = jnp.sum(p, axis=1)[None]
        o_ref[...] = jnp.dot(p, v)[None]

    @pl.when(kj > 0)
    def _accum():
        m_old = m_ref[0]
        m_new = jnp.maximum(m_old, tile_max)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = (l_ref[0] * alpha + jnp.sum(p, axis=1))[None]
        o_ref[...] = (o_ref[0] * alpha[:, None] + jnp.dot(p, v))[None]
        m_ref[...] = m_new[None]

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[...] = o_ref[...] / l_ref[...][..., None]


def _attention_impl(q, k, v, pad_len, blk_q, blk_k):
    B, H, T, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    tp = max(-(-T // blk_q) * blk_q, -(-T // blk_k) * blk_k)
    # round padded length up so both block sizes divide it
    import math

    tp = math.lcm(blk_q, blk_k) * -(-T // math.lcm(blk_q, blk_k))

    def pad_t(x):
        if tp == T:
            return x
        return jnp.concatenate(
            [x, jnp.zeros((B, H, tp - T, dh), x.dtype)], axis=2
        )

    qf = pad_t(q).reshape(B * H, tp, dh)
    kf = pad_t(k).reshape(B * H, tp, dh)
    vf = pad_t(v).reshape(B * H, tp, dh)
    padf = jnp.repeat(pad_len.astype(jnp.int32), H)  # (B*H,)
    nq = tp // blk_q
    nk = tp // blk_k
    kernel = functools.partial(
        _attn_kernel, blk_q=blk_q, blk_k=blk_k, nk=nk, scale=scale, t_real=T
    )
    o, _m, _l = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda i, qi, kj: (i, qi, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda i, qi, kj: (i, kj, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda i, qi, kj: (i, kj, 0)),
            pl.BlockSpec((1,), lambda i, qi, kj: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda i, qi, kj: (i, qi, 0)),
            pl.BlockSpec((1, blk_q), lambda i, qi, kj: (i, qi)),
            pl.BlockSpec((1, blk_q), lambda i, qi, kj: (i, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, tp, dh), jnp.float32),
            jax.ShapeDtypeStruct((B * H, tp), jnp.float32),
            jax.ShapeDtypeStruct((B * H, tp), jnp.float32),
        ],
        interpret=True,
    )(qf, kf, vf, padf)
    return o.reshape(B, H, tp, dh)[:, :, :T, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def attention(q, k, v, pad_len, blk_q=DEFAULT_BLK_Q, blk_k=DEFAULT_BLK_K):
    """Pallas flash attention: f32[B,H,T,dh] x3, i32[B] -> f32[B,H,T,dh].

    Matches :func:`ref.attention_ref`; differentiable w.r.t. q, k, v.
    """
    return _attention_impl(q, k, v, pad_len, blk_q, blk_k)


def _vjp_fwd(q, k, v, pad_len, blk_q, blk_k):
    o = _attention_impl(q, k, v, pad_len, blk_q, blk_k)
    return o, (q, k, v, pad_len)


def _vjp_bwd(blk_q, blk_k, res, g):
    q, k, v, pad_len = res
    # Rematerialised backward: differentiate the reference formulation.
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, pad_len), q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


attention.defvjp(_vjp_fwd, _vjp_bwd)


def attention_reference(q, k, v, pad_len):
    """Oracle re-export for tests/benchmarks."""
    return attention_ref(q, k, v, pad_len)
