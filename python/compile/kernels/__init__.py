"""L1 Pallas kernels: the compute hot spots, checked against ref.py oracles."""
