"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests`` asserts each Pallas
kernel (interpret mode) matches its oracle across hypothesis-swept shapes.
They are also used as the backward pass of the attention kernel's
``custom_vjp`` (recompute-based, see kernels/attention.py).
"""

import jax
import jax.numpy as jnp

NEG = -1e30  # finite "-inf": avoids inf-inf NaNs in online-softmax algebra


def logprob_ref(logits, labels):
    """Token log-probabilities.

    logits: f32[R, V], labels: i32[R]  ->  f32[R]
    (callers flatten [B, T, V] to [B*T, V])
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    lbl = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lbl - lse


def grpo_loss_ref(new_lp, old_lp, adv, mask, eps):
    """GRPO clipped surrogate, per-rollout objective.

    new_lp, old_lp, mask: f32[B, G]; adv: f32[B]; eps: python float.
    Returns (obj[B], clip_frac[B]) where obj is the per-rollout token-mean
    clipped objective of Eq. (2) and clip_frac the fraction of generated
    tokens where the clipped branch is strictly active.
    """
    ratio = jnp.exp(new_lp - old_lp)
    a = adv[:, None]
    unclipped = ratio * a
    clipped = jnp.clip(ratio, 1.0 - eps, 1.0 + eps) * a
    tok = jnp.minimum(unclipped, clipped) * mask
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    obj = jnp.sum(tok, axis=1) / cnt
    clip_frac = jnp.sum(jnp.where(clipped < unclipped, mask, 0.0), axis=1) / cnt
    return obj, clip_frac


def attention_ref(q, k, v, pad_len):
    """Causal, left-pad-masked multi-head attention.

    q, k, v: f32[B, H, T, dh]; pad_len: i32[B] (tokens < pad_len are padding).
    Key j is visible to query i iff pad_len <= j <= i.  Fully-masked query
    rows (i < pad_len, i.e. padding queries) degrade to uniform attention —
    finite garbage that downstream losses mask out.
    """
    B, H, T, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(T)[None, :]
    causal = kpos <= qpos  # [T, T]
    valid_k = jnp.arange(T)[None, None, None, :] >= pad_len[:, None, None, None]
    mask = causal[None, None, :, :] & valid_k  # [B, 1, T, T]
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def adamw_ref(p, g, m, v, step, lr, b1, b2, eps, wd):
    """Decoupled AdamW over flat vectors. step is the 0-based step index."""
    t = step + 1
    mn = b1 * m + (1.0 - b1) * g
    vn = b2 * v + (1.0 - b2) * g * g
    c1 = 1.0 / (1.0 - b1**t)
    c2 = 1.0 / (1.0 - b2**t)
    upd = (mn * c1) / (jnp.sqrt(vn * c2) + eps) + wd * p
    return p - lr * upd, mn, vn
